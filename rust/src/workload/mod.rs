//! Workloads: serving traces written by the build-time python
//! (`artifacts/traces/*.json`) plus a rust-native synthetic generator
//! for load tests where the trace pool is too small.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One request: a prompt and (for quality checks) the reference
/// continuation the corpus generator produced.
#[derive(Debug, Clone)]
pub struct TraceItem {
    pub prompt: Vec<u32>,
    pub reference: Vec<u32>,
}

/// Load a task trace (chat/math/code).
pub fn load_trace(path: &Path) -> Result<Vec<TraceItem>> {
    let j = Json::from_file(path).with_context(|| format!("loading trace {}", path.display()))?;
    let mut out = Vec::new();
    for item in j.as_arr()? {
        out.push(TraceItem {
            prompt: item.req("prompt")?.as_u32_vec()?,
            reference: item.req("reference")?.as_u32_vec()?,
        });
    }
    if out.is_empty() {
        bail!("empty trace {}", path.display());
    }
    Ok(out)
}

/// Load the validation token stream (REST datastore, accuracy evals).
pub fn load_val_stream(root: &Path) -> Result<Vec<u32>> {
    Json::from_file(&root.join("traces").join("val_ids.json"))?.as_u32_vec()
}

/// Rust-native synthetic prompt generator mirroring the corpus grammar
/// (byte-level).  Used by the server example for open-ended load.
pub struct WorkloadGen {
    rng: Rng,
}

const SUBJECTS: &[&str] = &["the sky", "a river", "the moon", "a forest", "the ocean"];
const ADJECTIVES: &[&str] = &["blue", "calm", "bright", "green", "vast"];
const TOPICS: &[&str] = &["color", "place", "season", "animal"];

impl WorkloadGen {
    pub fn new(seed: u64) -> Self {
        WorkloadGen { rng: Rng::new(seed) }
    }

    fn zipf<'a>(&mut self, items: &[&'a str]) -> &'a str {
        let weights: Vec<f64> = (0..items.len()).map(|i| 1.0 / (i + 1) as f64).collect();
        items[self.rng.weighted(&weights)]
    }

    pub fn chat_prompt(&mut self) -> Vec<u32> {
        let t = self.zipf(TOPICS);
        let a = self.zipf(ADJECTIVES);
        let s = self.zipf(SUBJECTS);
        let text = format!(
            "user: what is your favorite {t}?\nassistant: my favorite {t} is {a} because it reminds me of {s}.\nuser: which {t} do you like the most?\nassistant:"
        );
        encode(&text)
    }

    pub fn math_prompt(&mut self) -> Vec<u32> {
        let a = self.rng.range(2, 99);
        let b = self.rng.range(2, 99);
        let text = format!("calc: {a} + {b} = {} ; calc: {} + {} = ", a + b, a + 1, b);
        encode(&text)
    }

    pub fn code_prompt(&mut self) -> Vec<u32> {
        let text = "def add_a_b(a, b):\n    result = a + b\n    return result\n\ndef add_x_y(x, y):\n";
        encode(text)
    }

    pub fn mixed_prompt(&mut self) -> Vec<u32> {
        match self.rng.below(3) {
            0 => self.chat_prompt(),
            1 => self.math_prompt(),
            _ => self.code_prompt(),
        }
    }
}

/// Byte-level encode (identity over ASCII).
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().filter(|&b| b < 128).map(|b| b as u32).collect()
}

/// Byte-level decode for display.
pub fn decode(tokens: &[u32]) -> String {
    tokens
        .iter()
        .filter_map(|&t| {
            if (32..128).contains(&t) || t == 9 || t == 10 {
                Some(t as u8 as char)
            } else {
                None
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "calc: 1 + 2 = 3 ;\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn encode_drops_non_ascii() {
        assert_eq!(encode("a\u{00e9}b").len(), 2);
    }

    #[test]
    fn generator_is_deterministic() {
        let mut a = WorkloadGen::new(5);
        let mut b = WorkloadGen::new(5);
        assert_eq!(a.chat_prompt(), b.chat_prompt());
        assert_eq!(a.math_prompt(), b.math_prompt());
    }

    #[test]
    fn prompts_are_ascii_tokens() {
        let mut g = WorkloadGen::new(1);
        for _ in 0..10 {
            assert!(g.mixed_prompt().iter().all(|&t| t < 128));
        }
    }

    #[test]
    fn trace_loader_parses() {
        let dir = std::env::temp_dir().join("ppd_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        std::fs::write(&p, r#"[{"prompt":[1,2,3],"reference":[4,5]}]"#).unwrap();
        let t = load_trace(&p).unwrap();
        assert_eq!(t[0].prompt, vec![1, 2, 3]);
        assert_eq!(t[0].reference, vec![4, 5]);
        std::fs::write(&p, "[]").unwrap();
        assert!(load_trace(&p).is_err());
    }
}
