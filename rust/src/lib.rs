//! # PPD — Hardware-Aware Parallel Prompt Decoding
//!
//! Reproduction of Chen et al., EMNLP 2025 Findings (see DESIGN.md).
//! Three-layer stack: this rust crate is L3 (serving coordinator); the
//! JAX model (L2) and Pallas tree-attention kernel (L1) live under
//! `python/` and are AOT-compiled to HLO text loaded by [`runtime`].
//!
//! Quick tour:
//! * [`runtime`]  — PJRT executable loading + bucketed `forward`
//! * [`kvcache`]  — host-authoritative KV cache with tree compaction
//! * [`tree`]     — sparse trees; dynamic state machine (Props 4.1–4.4);
//!                  hardware-aware sizing
//! * [`decoding`] — vanilla / PPD / Medusa / lookup / speculative
//!                  engines, all resumable (`begin_seq`/`step`)
//! * [`batch`]    — fused batched stepping: plan/apply step split,
//!                  ragged-plan collation, one device call per tick —
//!                  and the shared-runtime `DeviceDispatcher`
//!                  (`--shared-runtime`): one device call per wall tick
//!                  across ALL workers
//! * [`coordinator`] — multi-worker serving layer: shared work queue,
//!                  step-level continuous batching (`--max-inflight`),
//!                  capped KV-cache pool, cancellation/queue-aging,
//!                  out-of-order completion, TCP server
//! * [`workload`] — trace loading + synthetic workload generation
//! * [`bench`]    — deterministic mock-backend scheduler sweep (the CI
//!                  `BENCH_sched.json` throughput trajectory)
//! * [`trace`]    — request-lifecycle flight recorder: bounded per-track
//!                  event rings, scripted-clock injection, Chrome
//!                  trace-event export (TCP `trace` request / Perfetto)
pub mod baselines;
pub mod batch;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod decoding;
pub mod kvcache;
pub mod metrics;
pub mod runtime;
pub mod trace;
pub mod tree;
pub mod util;
pub mod workload;
