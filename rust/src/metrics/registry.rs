//! Single source of truth for every `ppd_*` Prometheus metric the
//! serving stack exposes.
//!
//! The emission sites stay where they are (`QueueStats::to_prometheus`,
//! `DispatchStats::to_prometheus`, `Coordinator::metrics_text`) — this
//! module exists so the *names and label keys* live in exactly one
//! place.  `cargo xtask analyze` parses these tables and fails the
//! build when a `ppd_*` string literal anywhere in the crate drifts
//! from them, when a declared metric stops being emitted, or when a
//! name is missing from the README's metrics table.  Adding a metric
//! therefore means: emit it, declare it here, document it in README.md
//! — the analysis job enforces all three.

/// `(metric name, label keys, help text)`.
///
/// Kept as a tuple rather than a struct so the declaration below stays
/// a flat, machine-parseable literal table (the xtask check reads the
/// string literals positionally: first = name, last = help, middle =
/// labels).
pub type MetricDecl = (&'static str, &'static [&'static str], &'static str);

pub const METRICS: &[MetricDecl] = &[
    // -- shared work queue (QueueStats::to_prometheus) ----------------
    ("ppd_queue_enqueued_total", &[], "requests accepted into the shared work queue"),
    ("ppd_queue_completed_total", &[], "requests fully served"),
    ("ppd_queue_rejected_total", &[], "requests refused at admission (queue full)"),
    ("ppd_queue_expired_total", &[], "requests dropped by queue-age policy before starting"),
    ("ppd_queue_cancelled_total", &[], "requests cancelled by the client mid-flight"),
    ("ppd_queue_admitted_total", &[], "sequences admitted into a scheduler's inflight set"),
    ("ppd_queue_sched_steps_total", &[], "scheduler step-loop iterations"),
    ("ppd_queue_depth", &[], "requests parked in the queue right now"),
    ("ppd_queue_max_depth", &[], "high-water queue depth"),
    ("ppd_queue_in_flight", &[], "requests currently being served"),
    ("ppd_queue_busy_workers", &[], "workers currently inside a request"),
    ("ppd_queue_max_inflight_seqs", &[], "high-water per-worker inflight sequence count"),
    ("ppd_queue_fused_batches_total", &[], "fused multi-sequence device steps"),
    ("ppd_queue_fused_rows_total", &[], "sequence rows carried by fused steps"),
    ("ppd_queue_max_fused_batch", &[], "widest single fused step"),
    ("ppd_queue_fused_batch_size_total", &["batch"], "fused step count by batch width"),
    ("ppd_queue_capacity", &[], "configured queue capacity"),
    // -- shared-runtime dispatcher (DispatchStats::to_prometheus) -----
    ("ppd_dispatch_batches_total", &[], "cross-worker fused device dispatches"),
    ("ppd_dispatch_rows_total", &[], "rows across cross-worker dispatches"),
    ("ppd_dispatch_max_width", &[], "widest cross-worker dispatch"),
    ("ppd_dispatch_multi_worker_batches_total", &[], "dispatches fusing rows from >1 worker"),
    ("ppd_dispatch_solo_forwards_total", &[], "solo forwards served outside tick fusion"),
    ("ppd_dispatch_queue_depth", &[], "submissions parked at the dispatcher right now"),
    ("ppd_dispatch_max_queue_depth", &[], "high-water dispatcher queue depth"),
    ("ppd_dispatch_max_union_slot", &[], "highest KV slot any union referenced"),
    ("ppd_dispatch_width_total", &["width"], "cross-worker dispatch count by union width"),
    ("ppd_dispatch_kv_bucket_total", &["kv"], "fused dispatches by executed KV context"),
    ("ppd_dispatch_rows_by_worker", &["worker"], "fused rows attributed to submitting worker"),
    ("ppd_dispatch_overlap_batches_total", &[], "rounds assembled while the device still ran the previous round (pipelined overlap observed)"),
    ("ppd_dispatch_overlap_precollated_batches_total", &[], "fused rounds collated on the collector stage instead of inside the executor"),
    ("ppd_dispatch_device_busy_us_total", &[], "microseconds spent inside fused device executions (occupancy numerator)"),
    ("ppd_dispatch_window_us", &[], "current adaptive coalescing window in microseconds"),
    // -- runtime forward counters (Coordinator::metrics_text) ---------
    ("ppd_runtime_bucket_forwards_total", &["n", "kv"], "forwards by (token bucket, kv context)"),
    ("ppd_runtime_kv_forwards_total", &["kv"], "single-sequence forwards by kv context"),
    ("ppd_runtime_batch_kv_forwards_total", &["kv"], "batched forwards by kv context"),
    // -- coordinator gauges (Coordinator::metrics_text) ---------------
    ("ppd_workers", &[], "serving worker thread count"),
    ("ppd_shared_runtime", &[], "1 when the shared-runtime dispatcher topology is active"),
    ("ppd_caches_created", &[], "KV caches ever built by the capped pool"),
    ("ppd_caches_outstanding", &[], "KV caches currently checked out"),
    ("ppd_kvcache_blocks_used", &[], "distinct live KV pages (0 without --kv-blocks)"),
    ("ppd_kvcache_blocks_free", &[], "KV page budget headroom (0 without --kv-blocks)"),
    ("ppd_prefix_hits_total", &[], "admissions served shared prompt-prefix pages"),
    ("ppd_prefix_blocks_shared_total", &[], "KV pages handed out by reference from the prefix store"),
    // -- streaming / sessions / SLO scheduling (Coordinator::metrics_text)
    ("ppd_stream_events_total", &[], "ResponseEvent frames sent toward v2 streaming clients"),
    ("ppd_session_resumes_total", &[], "submitted requests that resumed a known session"),
    ("ppd_session_prefix_turn_hits_total", &[], "resumed session turns whose admission found their conversation's pages in the prefix store"),
    ("ppd_sched_preemptions_total", &[], "slo-discipline picks that jumped the FIFO queue head"),
    // -- per-request latency histograms (RequestLatency::to_prometheus)
    ("ppd_request_queue_wait_us", &["le"], "enqueue-to-admission wait, cumulative us buckets"),
    ("ppd_request_ttft_us", &["le"], "enqueue-to-first-token latency, cumulative us buckets"),
    ("ppd_request_itl_us", &["le"], "gap between token-emitting steps, cumulative us buckets"),
    ("ppd_request_e2e_us", &["le"], "enqueue-to-response latency, cumulative us buckets"),
    // -- trace flight recorder (Coordinator::metrics_text) ------------
    ("ppd_trace_ring_dropped_total", &[], "trace events overwritten in the bounded rings"),
];

/// Name prefixes the emission code concatenates suffixes onto (the
/// `push(suffix)` builders in `QueueStats::to_prometheus` and
/// `DispatchStats::to_prometheus`).  A string literal equal to one of
/// these is name-building, not an undeclared metric.
pub const METRIC_PREFIXES: &[&str] = &["ppd_queue_", "ppd_dispatch_"];

/// `ppd_*` string literals that are NOT metric names: temp-dir names in
/// tests and bench-local identifiers interpolated into messages.  The
/// xtask scan treats a literal starting with one of these as benign.
pub const NON_METRIC_ALLOW: &[&str] =
    &["ppd_cfg_test", "ppd_cal_test", "ppd_w_test", "ppd_stats_test", "ppd_trace_test"];

/// Look up a metric declaration by exact name.
pub fn find(name: &str) -> Option<&'static MetricDecl> {
    METRICS.iter().find(|m| m.0 == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_well_formed() {
        for (i, (name, _, help)) in METRICS.iter().enumerate() {
            let well_formed =
                name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
            assert!(name.starts_with("ppd_") && well_formed, "bad metric name {name}");
            assert!(!help.is_empty(), "{name} has no help text");
            assert!(
                !METRICS[..i].iter().any(|m| m.0 == *name),
                "duplicate metric declaration {name}"
            );
        }
    }

    /// Every line the live exporters emit must resolve to a declared
    /// metric with the declared label keys — the in-crate half of the
    /// drift guard (`cargo xtask analyze` covers the literal scan).
    #[test]
    fn exporter_output_matches_registry() {
        let queue = crate::metrics::QueueStats::new();
        let dispatch = crate::batch::dispatch::DispatchStats::default();
        let latency = crate::metrics::RequestLatency::default();
        for text in [queue.to_prometheus(), dispatch.to_prometheus(), latency.to_prometheus()] {
            for line in text.lines() {
                let name_part = line.split(' ').next().expect("metric line");
                let (name, labels) = match name_part.split_once('{') {
                    Some((n, rest)) => (n, Some(rest)),
                    None => (name_part, None),
                };
                let decl = find(name).unwrap_or_else(|| panic!("undeclared metric {name}"));
                if let Some(rest) = labels {
                    for kv in rest.trim_end_matches('}').split(',') {
                        let key = kv.split('=').next().expect("label key");
                        assert!(
                            decl.1.contains(&key),
                            "metric {name} emits undeclared label {key}"
                        );
                    }
                }
            }
        }
    }
}
