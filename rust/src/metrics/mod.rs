//! Serving metrics: latency histograms, throughput counters, queue
//! depth/backpressure gauges, and the aggregated report the
//! coordinator/benches emit.

pub mod registry;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::runtime::RuntimeStats;
use crate::util::json::Json;

/// Fixed-boundary latency histogram (log-spaced), allocation-free on the
/// hot path.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket upper bounds in seconds
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_s: f64,
    max_s: f64,
    n: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 100µs .. ~100s, 1.6x spacing
        let mut bounds = Vec::new();
        let mut b = 1e-4;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.6;
        }
        let len = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; len + 1], sum_s: 0.0, max_s: 0.0, n: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        let idx = self.bounds.partition_point(|&b| b < s);
        self.counts[idx] += 1;
        self.sum_s += s;
        self.max_s = self.max_s.max(s);
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_s(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_s / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bounds.get(i).copied().unwrap_or(self.max_s);
            }
        }
        self.max_s
    }
}

/// Work-queue + step-scheduler accounting shared between the
/// coordinator (producer side) and its workers (consumer side).  All
/// atomic — incremented on the submit/dispatch hot path without taking
/// the queue lock twice.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// requests accepted into the queue
    enqueued: AtomicU64,
    /// requests picked up by a worker
    dequeued: AtomicU64,
    /// requests fully served (response sent)
    completed: AtomicU64,
    /// requests refused by backpressure (`try_submit` over capacity)
    rejected: AtomicU64,
    /// high-water mark of the queue depth
    max_depth: AtomicU64,
    /// sequences currently admitted into a worker's step scheduler
    busy_workers: AtomicU64,
    /// sequences admitted into a step scheduler (post queue-age check)
    admitted: AtomicU64,
    /// individual decode steps executed across all schedulers
    sched_steps: AtomicU64,
    /// high-water mark of any single worker's in-flight sequence count
    max_inflight_seqs: AtomicU64,
    /// jobs dropped at admission because they aged out in the queue
    expired: AtomicU64,
    /// sequences aborted mid-flight by their cancel flag
    cancelled: AtomicU64,
    /// fused `forward_batch` calls issued by step schedulers
    fused_batches: AtomicU64,
    /// sequences served through those fused calls
    fused_rows: AtomicU64,
    /// largest single fused batch observed
    max_fused_batch: AtomicU64,
    /// per-tick fused batch-size histogram
    fused_hist: FusedHist,
    /// streaming frames (`Started`/`Tokens`/terminal) sent to v2 clients
    stream_events: AtomicU64,
    /// submitted requests that resumed an already-seen session
    session_resumes: AtomicU64,
    /// resumed session turns whose KV checkout hit cached prefix pages
    session_prefix_turn_hits: AtomicU64,
}

/// Histogram slots for the fused batch-size distribution: slot `i`
/// counts fused calls that served `i + 1` sequences; the last slot
/// aggregates everything at or beyond `FUSED_HIST_SLOTS` (a tick wider
/// than the slot count — reachable once fusion spans workers — is
/// **clamped** into it, never dropped; regression-tested in this module
/// and labeled `"16+"` in the Prometheus text).
pub const FUSED_HIST_SLOTS: usize = 16;

/// Prometheus label for a histogram slot reported by
/// [`FusedHist::nonzero`]: the overflow slot is `"16+"` so a scrape
/// can't mistake clamped wide ticks for exactly-16-row ticks.
pub fn fused_slot_label(batch: usize) -> String {
    if batch >= FUSED_HIST_SLOTS {
        format!("{FUSED_HIST_SLOTS}+")
    } else {
        batch.to_string()
    }
}

#[derive(Debug)]
pub struct FusedHist([AtomicU64; FUSED_HIST_SLOTS]);

impl Default for FusedHist {
    fn default() -> Self {
        FusedHist(std::array::from_fn(|_| AtomicU64::new(0)))
    }
}

impl FusedHist {
    /// Record one batch of `batch` rows, clamping oversize batches into
    /// the last (overflow) slot.
    pub fn record(&self, batch: usize) {
        let slot = batch.clamp(1, FUSED_HIST_SLOTS) - 1;
        self.0[slot].fetch_add(1, Ordering::Relaxed);
    }

    /// `(batch_size, count)` pairs for every non-empty slot; the entry
    /// at `FUSED_HIST_SLOTS` aggregates every batch at or beyond it.
    pub fn nonzero(&self) -> Vec<(usize, u64)> {
        self.0
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then_some((i + 1, n))
            })
            .collect()
    }
}

impl QueueStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an accepted enqueue at the given post-push depth.
    pub fn on_enqueue(&self, depth: usize) {
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        self.max_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_dequeue(&self) {
        self.dequeued.fetch_add(1, Ordering::Relaxed);
        self.busy_workers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.busy_workers.fetch_sub(1, Ordering::Relaxed);
    }

    /// Record an admission into a step scheduler whose in-flight set
    /// now holds `inflight_now` sequences.
    pub fn on_admit(&self, inflight_now: usize) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.max_inflight_seqs.fetch_max(inflight_now as u64, Ordering::Relaxed);
    }

    /// Record one decode step of one in-flight sequence.
    pub fn on_step(&self) {
        self.sched_steps.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job dropped at admission by the max-queue-age policy.
    pub fn on_expire(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a sequence aborted by its cancel flag.
    pub fn on_cancel(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` streaming event frames sent toward a v2 client.
    pub fn on_stream_events(&self, n: usize) {
        self.stream_events.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record a submitted request that resumes a known session.
    pub fn on_session_resume(&self) {
        self.session_resumes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a resumed session turn whose checkout found its
    /// conversation's pages still cached in the prefix store.
    pub fn on_session_prefix_turn_hit(&self) {
        self.session_prefix_turn_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one fused `forward_batch` call that served `batch`
    /// sequences in a single device dispatch.
    pub fn on_fused_batch(&self, batch: usize) {
        self.fused_batches.fetch_add(1, Ordering::Relaxed);
        self.fused_rows.fetch_add(batch as u64, Ordering::Relaxed);
        self.max_fused_batch.fetch_max(batch as u64, Ordering::Relaxed);
        self.fused_hist.record(batch);
    }

    /// Requests accepted but not yet picked up (the live queue depth).
    pub fn depth(&self) -> u64 {
        self.enqueued
            .load(Ordering::Relaxed)
            .saturating_sub(self.dequeued.load(Ordering::Relaxed))
    }

    /// Accepted but not yet completed (queued + running).
    pub fn in_flight(&self) -> u64 {
        self.enqueued
            .load(Ordering::Relaxed)
            .saturating_sub(self.completed.load(Ordering::Relaxed))
    }

    pub fn enqueued_total(&self) -> u64 {
        self.enqueued.load(Ordering::Relaxed)
    }

    pub fn completed_total(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn max_depth(&self) -> u64 {
        self.max_depth.load(Ordering::Relaxed)
    }

    pub fn busy_workers(&self) -> u64 {
        self.busy_workers.load(Ordering::Relaxed)
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn stream_events_total(&self) -> u64 {
        self.stream_events.load(Ordering::Relaxed)
    }

    pub fn session_resumes_total(&self) -> u64 {
        self.session_resumes.load(Ordering::Relaxed)
    }

    pub fn session_prefix_turn_hits_total(&self) -> u64 {
        self.session_prefix_turn_hits.load(Ordering::Relaxed)
    }

    pub fn sched_steps_total(&self) -> u64 {
        self.sched_steps.load(Ordering::Relaxed)
    }

    pub fn max_inflight_seqs(&self) -> u64 {
        self.max_inflight_seqs.load(Ordering::Relaxed)
    }

    pub fn expired_total(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }

    pub fn cancelled_total(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn fused_batches_total(&self) -> u64 {
        self.fused_batches.load(Ordering::Relaxed)
    }

    pub fn fused_rows_total(&self) -> u64 {
        self.fused_rows.load(Ordering::Relaxed)
    }

    pub fn max_fused_batch(&self) -> u64 {
        self.max_fused_batch.load(Ordering::Relaxed)
    }

    /// `(batch_size, count)` pairs of the fused batch-size histogram.
    pub fn fused_hist(&self) -> Vec<(usize, u64)> {
        self.fused_hist.nonzero()
    }

    /// All counters as one Prometheus-exposition-format text block
    /// (newline-separated `name value` lines) — what the TCP `metrics`
    /// request serves for shared-nothing scraping.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut push = |name: &str, v: u64| {
            out.push_str("ppd_queue_");
            out.push_str(name);
            out.push(' ');
            out.push_str(&v.to_string());
            out.push('\n');
        };
        push("enqueued_total", self.enqueued_total());
        push("completed_total", self.completed_total());
        push("rejected_total", self.rejected_total());
        push("depth", self.depth());
        push("in_flight", self.in_flight());
        push("max_depth", self.max_depth());
        push("busy_workers", self.busy_workers());
        push("admitted_total", self.admitted_total());
        push("sched_steps_total", self.sched_steps_total());
        push("max_inflight_seqs", self.max_inflight_seqs());
        push("expired_total", self.expired_total());
        push("cancelled_total", self.cancelled_total());
        push("fused_batches_total", self.fused_batches_total());
        push("fused_rows_total", self.fused_rows_total());
        push("max_fused_batch", self.max_fused_batch());
        for (b, c) in self.fused_hist() {
            let label = fused_slot_label(b);
            out.push_str(&format!("ppd_queue_fused_batch_size_total{{batch=\"{label}\"}} {c}\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enqueued", Json::Num(self.enqueued_total() as f64)),
            ("completed", Json::Num(self.completed_total() as f64)),
            ("rejected", Json::Num(self.rejected_total() as f64)),
            ("depth", Json::Num(self.depth() as f64)),
            ("in_flight", Json::Num(self.in_flight() as f64)),
            ("max_depth", Json::Num(self.max_depth() as f64)),
            ("busy_workers", Json::Num(self.busy_workers() as f64)),
            ("admitted", Json::Num(self.admitted_total() as f64)),
            ("sched_steps", Json::Num(self.sched_steps_total() as f64)),
            ("max_inflight_seqs", Json::Num(self.max_inflight_seqs() as f64)),
            ("expired", Json::Num(self.expired_total() as f64)),
            ("cancelled", Json::Num(self.cancelled_total() as f64)),
            ("fused_batches", Json::Num(self.fused_batches_total() as f64)),
            ("fused_rows", Json::Num(self.fused_rows_total() as f64)),
            ("max_fused_batch", Json::Num(self.max_fused_batch() as f64)),
        ])
    }
}

/// Thread-safe aggregate of per-worker [`RuntimeStats`]: each worker
/// owns its `Runtime` (the PJRT client is not `Send`), so device-call
/// counters are flushed here when the worker drains — the coordinator
/// keeps a handle that outlives the workers, which is how a serving run
/// reports forwards-per-token after shutdown.
#[derive(Debug, Default)]
pub struct RuntimeAgg {
    inner: Mutex<RuntimeStats>,
}

impl RuntimeAgg {
    pub fn absorb(&self, stats: &RuntimeStats) {
        self.inner.lock().unwrap().absorb(stats);
    }

    pub fn snapshot(&self) -> RuntimeStats {
        self.inner.lock().unwrap().clone()
    }
}

/// Fixed µs bucket upper bounds shared by all `ppd_request_*_us`
/// histograms: a ×2 ladder from 100µs to ~13s.  Fixed (not adaptive)
/// so scrapes from different workers/runs are always mergeable and the
/// deterministic harness can recompute the exact bucket counts.
pub const REQUEST_US_BOUNDS: &[u64] = &[
    100,
    200,
    400,
    800,
    1_600,
    3_200,
    6_400,
    12_800,
    25_600,
    51_200,
    102_400,
    204_800,
    409_600,
    819_200,
    1_638_400,
    3_276_800,
    6_553_600,
    13_107_200,
];

/// Bucket-boundary quantile estimate over non-cumulative per-bucket
/// counts laid out as [`REQUEST_US_BOUNDS`] plus one overflow slot.
/// Shared (pub) so tests can recompute quantiles from scraped bucket
/// lines and compare them against the live histogram exactly.
pub fn us_bucket_quantile(counts: &[u64], q: f64) -> f64 {
    let n: u64 = counts.iter().sum();
    if n == 0 {
        return 0.0;
    }
    let target = (q * n as f64).ceil().max(1.0) as u64;
    let mut acc = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return REQUEST_US_BOUNDS.get(i).map_or(f64::INFINITY, |&b| b as f64);
        }
    }
    f64::INFINITY
}

/// Atomic fixed-bucket histogram over microsecond samples — the
/// always-on backing store for the per-request latency metrics.
/// Recording is two relaxed atomic adds; no locks, no allocation.
#[derive(Debug)]
pub struct UsHistogram {
    /// one slot per bound plus the overflow (+Inf) slot
    counts: Vec<AtomicU64>,
}

impl Default for UsHistogram {
    fn default() -> Self {
        UsHistogram {
            counts: (0..=REQUEST_US_BOUNDS.len()).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

impl UsHistogram {
    pub fn record(&self, us: u64) {
        let idx = REQUEST_US_BOUNDS.partition_point(|&b| b < us);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Non-cumulative per-bucket counts ([`REQUEST_US_BOUNDS`] order,
    /// overflow slot last).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Bucket-boundary quantile estimate (upper bound of the target
    /// bucket; `+Inf` when the sample landed in the overflow slot).
    pub fn quantile_us(&self, q: f64) -> f64 {
        us_bucket_quantile(&self.bucket_counts(), q)
    }
}

/// Snapshot of the raw latency samples (µs) kept when
/// [`RequestLatency::set_keep_samples`] is on — the bench sweep uses
/// these to compute exact interpolated quantiles rather than
/// bucket-boundary estimates.
#[derive(Debug, Clone, Default)]
pub struct LatencySamples {
    pub ttft_us: Vec<u64>,
    pub itl_us: Vec<u64>,
    pub e2e_us: Vec<u64>,
    pub queue_wait_us: Vec<u64>,
}

/// The four per-request latency histograms the coordinator exports:
///
/// * **queue_wait** — enqueue → admission into a step scheduler
/// * **ttft** — enqueue → first emitted token (time-to-first-token)
/// * **itl** — gap between consecutive token-emitting steps
///   (inter-token latency; one sample per emitting step after the first)
/// * **e2e** — enqueue → response sent
///
/// All timestamps come from the coordinator's trace clock, so the trace
/// event stream and these histograms describe the same timeline — a
/// property the deterministic harness asserts.  Always on (unlike the
/// trace rings): recording is a handful of relaxed atomics per step.
#[derive(Debug, Default)]
pub struct RequestLatency {
    ttft: UsHistogram,
    itl: UsHistogram,
    e2e: UsHistogram,
    queue_wait: UsHistogram,
    keep: std::sync::atomic::AtomicBool,
    samples: Mutex<LatencySamples>,
}

impl RequestLatency {
    pub fn record_queue_wait(&self, us: u64) {
        self.queue_wait.record(us);
        if self.keep.load(Ordering::Relaxed) {
            self.samples.lock().unwrap().queue_wait_us.push(us);
        }
    }

    pub fn record_ttft(&self, us: u64) {
        self.ttft.record(us);
        if self.keep.load(Ordering::Relaxed) {
            self.samples.lock().unwrap().ttft_us.push(us);
        }
    }

    pub fn record_itl(&self, us: u64) {
        self.itl.record(us);
        if self.keep.load(Ordering::Relaxed) {
            self.samples.lock().unwrap().itl_us.push(us);
        }
    }

    pub fn record_e2e(&self, us: u64) {
        self.e2e.record(us);
        if self.keep.load(Ordering::Relaxed) {
            self.samples.lock().unwrap().e2e_us.push(us);
        }
    }

    /// Also retain raw samples (off by default; the bench sweep turns it
    /// on to compute exact interpolated p50/p95/p99).
    pub fn set_keep_samples(&self, on: bool) {
        self.keep.store(on, Ordering::Relaxed);
    }

    pub fn samples(&self) -> LatencySamples {
        self.samples.lock().unwrap().clone()
    }

    pub fn ttft(&self) -> &UsHistogram {
        &self.ttft
    }

    pub fn itl(&self) -> &UsHistogram {
        &self.itl
    }

    pub fn e2e(&self) -> &UsHistogram {
        &self.e2e
    }

    pub fn queue_wait(&self) -> &UsHistogram {
        &self.queue_wait
    }

    /// Prometheus text: cumulative `{le="..."}` bucket lines (all
    /// buckets, `+Inf` last) for each of the four histograms — the block
    /// `Coordinator::metrics_text` appends to the queue/dispatch text.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let hists: [(&str, &UsHistogram); 4] = [
            ("ppd_request_queue_wait_us", &self.queue_wait),
            ("ppd_request_ttft_us", &self.ttft),
            ("ppd_request_itl_us", &self.itl),
            ("ppd_request_e2e_us", &self.e2e),
        ];
        for (name, h) in hists {
            let mut acc = 0u64;
            for (i, c) in h.bucket_counts().into_iter().enumerate() {
                acc += c;
                let le = REQUEST_US_BOUNDS
                    .get(i)
                    .map_or_else(|| "+Inf".to_string(), |b| b.to_string());
                out.push_str(&format!("{name}{{le=\"{le}\"}} {acc}\n"));
            }
        }
        out
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: u64,
    pub generated_tokens: u64,
    pub decode_steps: u64,
    pub wall_s: f64,
    pub request_latency: Option<Box<LatencyHistogram>>,
    /// sequences admitted into step schedulers (from [`QueueStats`])
    pub admitted: u64,
    /// scheduler decode steps executed (from [`QueueStats`])
    pub sched_steps: u64,
    /// high-water mark of per-worker in-flight depth (from [`QueueStats`])
    pub peak_inflight: u64,
    /// jobs dropped by the max-queue-age policy
    pub expired: u64,
    /// sequences aborted by cancellation
    pub cancelled: u64,
    /// fused `forward_batch` calls (from [`QueueStats`])
    pub fused_batches: u64,
    /// sequences served through fused calls (from [`QueueStats`])
    pub fused_rows: u64,
    /// largest single fused batch (from [`QueueStats`])
    pub max_fused_batch: u64,
    /// fused batch-size histogram `(batch, count)` (from [`QueueStats`])
    pub fused_hist: Vec<(usize, u64)>,
}

impl ServeReport {
    pub fn new() -> Self {
        ServeReport { request_latency: Some(Box::default()), ..Default::default() }
    }

    pub fn record_request(&mut self, tokens: usize, steps: usize, latency: Duration) {
        self.requests += 1;
        self.generated_tokens += tokens as u64;
        self.decode_steps += steps as u64;
        if let Some(h) = self.request_latency.as_mut() {
            h.record(latency);
        }
    }

    /// Copy the scheduler-side counters out of the live [`QueueStats`]
    /// (call once at the end of a serving run).
    pub fn absorb_queue_stats(&mut self, q: &QueueStats) {
        self.admitted = q.admitted_total();
        self.sched_steps = q.sched_steps_total();
        self.peak_inflight = q.max_inflight_seqs();
        self.expired = q.expired_total();
        self.cancelled = q.cancelled_total();
        self.fused_batches = q.fused_batches_total();
        self.fused_rows = q.fused_rows_total();
        self.max_fused_batch = q.max_fused_batch();
        self.fused_hist = q.fused_hist();
    }

    /// Mean sequences per fused device call (0 when fusion never ran).
    pub fn mean_fused_batch(&self) -> f64 {
        if self.fused_batches == 0 {
            0.0
        } else {
            self.fused_rows as f64 / self.fused_batches as f64
        }
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    pub fn mean_tau(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.decode_steps as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let h = self.request_latency.as_deref();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("generated_tokens", Json::Num(self.generated_tokens as f64)),
            ("decode_steps", Json::Num(self.decode_steps as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("mean_tau", Json::Num(self.mean_tau())),
            ("p50_latency_s", Json::Num(h.map_or(0.0, |h| h.quantile_s(0.5)))),
            ("p95_latency_s", Json::Num(h.map_or(0.0, |h| h.quantile_s(0.95)))),
            ("mean_latency_s", Json::Num(h.map_or(0.0, |h| h.mean_s()))),
            ("admitted", Json::Num(self.admitted as f64)),
            ("sched_steps", Json::Num(self.sched_steps as f64)),
            ("peak_inflight", Json::Num(self.peak_inflight as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("fused_batches", Json::Num(self.fused_batches as f64)),
            ("fused_rows", Json::Num(self.fused_rows as f64)),
            ("max_fused_batch", Json::Num(self.max_fused_batch as f64)),
            ("mean_fused_batch", Json::Num(self.mean_fused_batch())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 5, 8, 13, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_s(0.5) <= h.quantile_s(0.95));
        assert!(h.mean_s() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_s(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn queue_stats_track_lifecycle() {
        let q = QueueStats::new();
        q.on_enqueue(1);
        q.on_enqueue(2);
        q.on_reject();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
        q.on_dequeue();
        assert_eq!(q.depth(), 1);
        assert_eq!(q.busy_workers(), 1);
        assert_eq!(q.in_flight(), 2);
        q.on_complete();
        assert_eq!(q.busy_workers(), 0);
        assert_eq!(q.in_flight(), 1);
        assert_eq!(q.rejected_total(), 1);
        let j = q.to_json();
        assert_eq!(j.req("enqueued").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("rejected").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn scheduler_counters_track_admission_and_steps() {
        let q = QueueStats::new();
        q.on_enqueue(1);
        q.on_dequeue();
        q.on_admit(1);
        q.on_enqueue(1);
        q.on_dequeue();
        q.on_admit(2);
        assert_eq!(q.admitted_total(), 2);
        assert_eq!(q.max_inflight_seqs(), 2);
        // busy_workers doubles as the live in-flight sequence gauge
        assert_eq!(q.busy_workers(), 2);
        q.on_step();
        q.on_step();
        q.on_step();
        assert_eq!(q.sched_steps_total(), 3);
        q.on_expire();
        q.on_cancel();
        assert_eq!(q.expired_total(), 1);
        assert_eq!(q.cancelled_total(), 1);
        q.on_complete();
        q.on_complete();
        assert_eq!(q.busy_workers(), 0);
        let j = q.to_json();
        assert_eq!(j.req("admitted").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("sched_steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("max_inflight_seqs").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("expired").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("cancelled").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn oversized_fused_batches_clamp_into_the_overflow_slot() {
        // regression: >FUSED_HIST_SLOTS-row ticks are routine once
        // fusion spans workers (N workers × max-inflight rows per wall
        // tick) — they must land in the clamped last slot, labeled
        // "16+" in the scrape, never be dropped
        let q = QueueStats::new();
        q.on_fused_batch(FUSED_HIST_SLOTS + 1);
        q.on_fused_batch(64);
        assert_eq!(q.fused_hist(), vec![(FUSED_HIST_SLOTS, 2)]);
        assert_eq!(q.fused_rows_total(), (FUSED_HIST_SLOTS + 1 + 64) as u64);
        assert_eq!(q.fused_batches_total(), 2);
        let text = q.to_prometheus();
        assert!(
            text.contains("ppd_queue_fused_batch_size_total{batch=\"16+\"} 2\n"),
            "{text}"
        );
        assert!(!text.contains("batch=\"17\""), "{text}");
        assert_eq!(fused_slot_label(3), "3");
        assert_eq!(fused_slot_label(FUSED_HIST_SLOTS), "16+");
        assert_eq!(fused_slot_label(40), "16+");
    }

    #[test]
    fn fused_counters_and_histogram() {
        let q = QueueStats::new();
        q.on_fused_batch(1);
        q.on_fused_batch(3);
        q.on_fused_batch(3);
        q.on_fused_batch(40); // clamps into the top slot
        assert_eq!(q.fused_batches_total(), 4);
        assert_eq!(q.fused_rows_total(), 1 + 3 + 3 + 40);
        assert_eq!(q.max_fused_batch(), 40);
        let hist = q.fused_hist();
        assert!(hist.contains(&(1, 1)));
        assert!(hist.contains(&(3, 2)));
        assert!(hist.contains(&(FUSED_HIST_SLOTS, 1)));
        let j = q.to_json();
        assert_eq!(j.req("fused_batches").unwrap().as_usize().unwrap(), 4);
        assert_eq!(j.req("max_fused_batch").unwrap().as_usize().unwrap(), 40);
    }

    #[test]
    fn prometheus_text_carries_counters() {
        let q = QueueStats::new();
        q.on_enqueue(1);
        q.on_dequeue();
        q.on_admit(1);
        q.on_fused_batch(2);
        q.on_complete();
        let text = q.to_prometheus();
        assert!(text.contains("ppd_queue_enqueued_total 1\n"), "{text}");
        assert!(text.contains("ppd_queue_completed_total 1\n"), "{text}");
        assert!(text.contains("ppd_queue_fused_batches_total 1\n"), "{text}");
        assert!(text.contains("ppd_queue_fused_batch_size_total{batch=\"2\"} 1\n"), "{text}");
        // every line is `name value` (prometheus exposition style)
        for line in text.lines() {
            assert_eq!(line.split(' ').count(), 2, "bad line {line}");
        }
    }

    #[test]
    fn runtime_agg_absorbs_across_workers() {
        let agg = RuntimeAgg::default();
        let a = RuntimeStats {
            forwards: 10,
            forward_batches: 3,
            batch_rows: 9,
            per_batch: [(3, 3)].into_iter().collect(),
            // (tree bucket, kv context) keys: the full-ctx and short-KV
            // executions of the same tree bucket stay separate lines
            per_bucket: [((16, 512), (2, 0.5)), ((16, 256), (8, 0.25))]
                .into_iter()
                .collect(),
            per_kv: [(512usize, 2usize), (256, 8)].into_iter().collect(),
            batch_per_kv: [(256usize, 3usize)].into_iter().collect(),
            ..Default::default()
        };
        agg.absorb(&a);
        let b = RuntimeStats {
            forwards: 5,
            forward_batches: 1,
            batch_rows: 2,
            per_batch: [(2, 1)].into_iter().collect(),
            per_bucket: [((16, 256), (1, 0.25))].into_iter().collect(),
            per_kv: [(256usize, 1usize)].into_iter().collect(),
            batch_per_kv: [(256usize, 1usize), (512, 2)].into_iter().collect(),
            ..Default::default()
        };
        agg.absorb(&b);
        let snap = agg.snapshot();
        assert_eq!(snap.forwards, 15);
        assert_eq!(snap.forward_batches, 4);
        assert_eq!(snap.batch_rows, 11);
        assert_eq!(snap.per_batch.get(&3), Some(&3));
        assert!((snap.mean_batch_rows() - 2.75).abs() < 1e-9);
        // kv-variant usage merges under its own key — it must never be
        // aggregated into the full-ctx line of the same tree bucket
        assert_eq!(snap.per_bucket.get(&(16, 512)), Some(&(2, 0.5)));
        assert_eq!(snap.per_bucket.get(&(16, 256)), Some(&(9, 0.5)));
        assert_eq!(snap.per_kv.get(&256), Some(&9));
        assert_eq!(snap.per_kv.get(&512), Some(&2));
        assert_eq!(snap.batch_per_kv.get(&256), Some(&4));
        assert_eq!(snap.batch_per_kv.get(&512), Some(&2));
    }

    #[test]
    fn runtime_agg_merges_per_worker_row_attribution() {
        // the shared dispatcher and worker-owned runtimes both flush
        // rows_by_worker fragments; the aggregate must merge, not clobber
        let agg = RuntimeAgg::default();
        agg.absorb(&RuntimeStats {
            rows_by_worker: [(0usize, 4usize), (1, 2)].into_iter().collect(),
            ..Default::default()
        });
        agg.absorb(&RuntimeStats {
            rows_by_worker: [(1usize, 3usize), (2, 7)].into_iter().collect(),
            ..Default::default()
        });
        let snap = agg.snapshot();
        assert_eq!(snap.rows_by_worker.get(&0), Some(&4));
        assert_eq!(snap.rows_by_worker.get(&1), Some(&5));
        assert_eq!(snap.rows_by_worker.get(&2), Some(&7));
    }

    #[test]
    fn report_absorbs_queue_stats() {
        let q = QueueStats::new();
        q.on_admit(3);
        q.on_step();
        q.on_expire();
        let mut r = ServeReport::new();
        r.absorb_queue_stats(&q);
        assert_eq!(r.admitted, 1);
        assert_eq!(r.sched_steps, 1);
        assert_eq!(r.peak_inflight, 3);
        assert_eq!(r.expired, 1);
        let j = r.to_json();
        assert_eq!(j.req("peak_inflight").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn us_histogram_buckets_and_quantiles() {
        let h = UsHistogram::default();
        // 100 lands in the first bucket (le="100"), 101 in the second.
        h.record(100);
        h.record(101);
        h.record(5_000);
        h.record(1_000_000_000); // overflow slot
        assert_eq!(h.count(), 4);
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), REQUEST_US_BOUNDS.len() + 1);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
        assert_eq!(*counts.last().unwrap(), 1);
        assert_eq!(h.quantile_us(0.25), 100.0);
        assert_eq!(h.quantile_us(0.75), 6_400.0);
        assert!(h.quantile_us(1.0).is_infinite());
        // the shared recompute helper agrees with the live histogram
        for q in [0.25, 0.5, 0.75, 0.99] {
            assert_eq!(h.quantile_us(q), us_bucket_quantile(&counts, q));
        }
        assert_eq!(us_bucket_quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn request_latency_prometheus_is_cumulative_with_inf() {
        let lat = RequestLatency::default();
        lat.record_ttft(150);
        lat.record_ttft(150);
        lat.record_ttft(300);
        let text = lat.to_prometheus();
        assert!(text.contains("ppd_request_ttft_us{le=\"200\"} 2\n"), "{text}");
        assert!(text.contains("ppd_request_ttft_us{le=\"400\"} 3\n"), "{text}");
        assert!(text.contains("ppd_request_ttft_us{le=\"+Inf\"} 3\n"), "{text}");
        // empty histograms still emit their full bucket ladder
        assert!(text.contains("ppd_request_itl_us{le=\"+Inf\"} 0\n"), "{text}");
        assert!(text.contains("ppd_request_e2e_us{le=\"+Inf\"} 0\n"), "{text}");
        assert!(text.contains("ppd_request_queue_wait_us{le=\"100\"} 0\n"), "{text}");
        // every line is `name{le="..."} value` — two space-split tokens
        for line in text.lines() {
            assert_eq!(line.split(' ').count(), 2, "bad line {line}");
        }
        let lines = text.lines().count();
        assert_eq!(lines, 4 * (REQUEST_US_BOUNDS.len() + 1));
    }

    #[test]
    fn request_latency_keeps_samples_only_when_asked() {
        let lat = RequestLatency::default();
        lat.record_e2e(500);
        assert!(lat.samples().e2e_us.is_empty());
        lat.set_keep_samples(true);
        lat.record_e2e(700);
        lat.record_itl(10);
        lat.record_queue_wait(3);
        let s = lat.samples();
        assert_eq!(s.e2e_us, vec![700]);
        assert_eq!(s.itl_us, vec![10]);
        assert_eq!(s.queue_wait_us, vec![3]);
        // the histogram saw both samples regardless of the gate
        assert_eq!(lat.e2e().count(), 2);
    }

    #[test]
    fn report_aggregates() {
        let mut r = ServeReport::new();
        r.record_request(10, 5, Duration::from_millis(100));
        r.record_request(20, 5, Duration::from_millis(200));
        r.wall_s = 2.0;
        assert_eq!(r.throughput_tok_s(), 15.0);
        assert_eq!(r.mean_tau(), 3.0);
        let j = r.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize().unwrap(), 2);
    }
}
