//! Serving metrics: latency histograms, throughput counters, queue
//! depth/backpressure gauges, and the aggregated report the
//! coordinator/benches emit.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Fixed-boundary latency histogram (log-spaced), allocation-free on the
/// hot path.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket upper bounds in seconds
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_s: f64,
    max_s: f64,
    n: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 100µs .. ~100s, 1.6x spacing
        let mut bounds = Vec::new();
        let mut b = 1e-4;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.6;
        }
        let len = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; len + 1], sum_s: 0.0, max_s: 0.0, n: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        let idx = self.bounds.partition_point(|&b| b < s);
        self.counts[idx] += 1;
        self.sum_s += s;
        self.max_s = self.max_s.max(s);
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_s(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_s / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bounds.get(i).copied().unwrap_or(self.max_s);
            }
        }
        self.max_s
    }
}

/// Work-queue + step-scheduler accounting shared between the
/// coordinator (producer side) and its workers (consumer side).  All
/// atomic — incremented on the submit/dispatch hot path without taking
/// the queue lock twice.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// requests accepted into the queue
    enqueued: AtomicU64,
    /// requests picked up by a worker
    dequeued: AtomicU64,
    /// requests fully served (response sent)
    completed: AtomicU64,
    /// requests refused by backpressure (`try_submit` over capacity)
    rejected: AtomicU64,
    /// high-water mark of the queue depth
    max_depth: AtomicU64,
    /// sequences currently admitted into a worker's step scheduler
    busy_workers: AtomicU64,
    /// sequences admitted into a step scheduler (post queue-age check)
    admitted: AtomicU64,
    /// individual decode steps executed across all schedulers
    sched_steps: AtomicU64,
    /// high-water mark of any single worker's in-flight sequence count
    max_inflight_seqs: AtomicU64,
    /// jobs dropped at admission because they aged out in the queue
    expired: AtomicU64,
    /// sequences aborted mid-flight by their cancel flag
    cancelled: AtomicU64,
}

impl QueueStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an accepted enqueue at the given post-push depth.
    pub fn on_enqueue(&self, depth: usize) {
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        self.max_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_dequeue(&self) {
        self.dequeued.fetch_add(1, Ordering::Relaxed);
        self.busy_workers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_complete(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.busy_workers.fetch_sub(1, Ordering::Relaxed);
    }

    /// Record an admission into a step scheduler whose in-flight set
    /// now holds `inflight_now` sequences.
    pub fn on_admit(&self, inflight_now: usize) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.max_inflight_seqs.fetch_max(inflight_now as u64, Ordering::Relaxed);
    }

    /// Record one decode step of one in-flight sequence.
    pub fn on_step(&self) {
        self.sched_steps.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a job dropped at admission by the max-queue-age policy.
    pub fn on_expire(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a sequence aborted by its cancel flag.
    pub fn on_cancel(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests accepted but not yet picked up (the live queue depth).
    pub fn depth(&self) -> u64 {
        self.enqueued
            .load(Ordering::Relaxed)
            .saturating_sub(self.dequeued.load(Ordering::Relaxed))
    }

    /// Accepted but not yet completed (queued + running).
    pub fn in_flight(&self) -> u64 {
        self.enqueued
            .load(Ordering::Relaxed)
            .saturating_sub(self.completed.load(Ordering::Relaxed))
    }

    pub fn enqueued_total(&self) -> u64 {
        self.enqueued.load(Ordering::Relaxed)
    }

    pub fn completed_total(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn max_depth(&self) -> u64 {
        self.max_depth.load(Ordering::Relaxed)
    }

    pub fn busy_workers(&self) -> u64 {
        self.busy_workers.load(Ordering::Relaxed)
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn sched_steps_total(&self) -> u64 {
        self.sched_steps.load(Ordering::Relaxed)
    }

    pub fn max_inflight_seqs(&self) -> u64 {
        self.max_inflight_seqs.load(Ordering::Relaxed)
    }

    pub fn expired_total(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }

    pub fn cancelled_total(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enqueued", Json::Num(self.enqueued_total() as f64)),
            ("completed", Json::Num(self.completed_total() as f64)),
            ("rejected", Json::Num(self.rejected_total() as f64)),
            ("depth", Json::Num(self.depth() as f64)),
            ("in_flight", Json::Num(self.in_flight() as f64)),
            ("max_depth", Json::Num(self.max_depth() as f64)),
            ("busy_workers", Json::Num(self.busy_workers() as f64)),
            ("admitted", Json::Num(self.admitted_total() as f64)),
            ("sched_steps", Json::Num(self.sched_steps_total() as f64)),
            ("max_inflight_seqs", Json::Num(self.max_inflight_seqs() as f64)),
            ("expired", Json::Num(self.expired_total() as f64)),
            ("cancelled", Json::Num(self.cancelled_total() as f64)),
        ])
    }
}

/// Aggregated serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: u64,
    pub generated_tokens: u64,
    pub decode_steps: u64,
    pub wall_s: f64,
    pub request_latency: Option<Box<LatencyHistogram>>,
    /// sequences admitted into step schedulers (from [`QueueStats`])
    pub admitted: u64,
    /// scheduler decode steps executed (from [`QueueStats`])
    pub sched_steps: u64,
    /// high-water mark of per-worker in-flight depth (from [`QueueStats`])
    pub peak_inflight: u64,
    /// jobs dropped by the max-queue-age policy
    pub expired: u64,
    /// sequences aborted by cancellation
    pub cancelled: u64,
}

impl ServeReport {
    pub fn new() -> Self {
        ServeReport { request_latency: Some(Box::default()), ..Default::default() }
    }

    pub fn record_request(&mut self, tokens: usize, steps: usize, latency: Duration) {
        self.requests += 1;
        self.generated_tokens += tokens as u64;
        self.decode_steps += steps as u64;
        if let Some(h) = self.request_latency.as_mut() {
            h.record(latency);
        }
    }

    /// Copy the scheduler-side counters out of the live [`QueueStats`]
    /// (call once at the end of a serving run).
    pub fn absorb_queue_stats(&mut self, q: &QueueStats) {
        self.admitted = q.admitted_total();
        self.sched_steps = q.sched_steps_total();
        self.peak_inflight = q.max_inflight_seqs();
        self.expired = q.expired_total();
        self.cancelled = q.cancelled_total();
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    pub fn mean_tau(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.decode_steps as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let h = self.request_latency.as_deref();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("generated_tokens", Json::Num(self.generated_tokens as f64)),
            ("decode_steps", Json::Num(self.decode_steps as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("mean_tau", Json::Num(self.mean_tau())),
            ("p50_latency_s", Json::Num(h.map_or(0.0, |h| h.quantile_s(0.5)))),
            ("p95_latency_s", Json::Num(h.map_or(0.0, |h| h.quantile_s(0.95)))),
            ("mean_latency_s", Json::Num(h.map_or(0.0, |h| h.mean_s()))),
            ("admitted", Json::Num(self.admitted as f64)),
            ("sched_steps", Json::Num(self.sched_steps as f64)),
            ("peak_inflight", Json::Num(self.peak_inflight as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 5, 8, 13, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile_s(0.5) <= h.quantile_s(0.95));
        assert!(h.mean_s() > 0.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_s(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn queue_stats_track_lifecycle() {
        let q = QueueStats::new();
        q.on_enqueue(1);
        q.on_enqueue(2);
        q.on_reject();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
        q.on_dequeue();
        assert_eq!(q.depth(), 1);
        assert_eq!(q.busy_workers(), 1);
        assert_eq!(q.in_flight(), 2);
        q.on_complete();
        assert_eq!(q.busy_workers(), 0);
        assert_eq!(q.in_flight(), 1);
        assert_eq!(q.rejected_total(), 1);
        let j = q.to_json();
        assert_eq!(j.req("enqueued").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("rejected").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn scheduler_counters_track_admission_and_steps() {
        let q = QueueStats::new();
        q.on_enqueue(1);
        q.on_dequeue();
        q.on_admit(1);
        q.on_enqueue(1);
        q.on_dequeue();
        q.on_admit(2);
        assert_eq!(q.admitted_total(), 2);
        assert_eq!(q.max_inflight_seqs(), 2);
        // busy_workers doubles as the live in-flight sequence gauge
        assert_eq!(q.busy_workers(), 2);
        q.on_step();
        q.on_step();
        q.on_step();
        assert_eq!(q.sched_steps_total(), 3);
        q.on_expire();
        q.on_cancel();
        assert_eq!(q.expired_total(), 1);
        assert_eq!(q.cancelled_total(), 1);
        q.on_complete();
        q.on_complete();
        assert_eq!(q.busy_workers(), 0);
        let j = q.to_json();
        assert_eq!(j.req("admitted").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("sched_steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("max_inflight_seqs").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("expired").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("cancelled").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn report_absorbs_queue_stats() {
        let q = QueueStats::new();
        q.on_admit(3);
        q.on_step();
        q.on_expire();
        let mut r = ServeReport::new();
        r.absorb_queue_stats(&q);
        assert_eq!(r.admitted, 1);
        assert_eq!(r.sched_steps, 1);
        assert_eq!(r.peak_inflight, 3);
        assert_eq!(r.expired, 1);
        let j = r.to_json();
        assert_eq!(j.req("peak_inflight").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn report_aggregates() {
        let mut r = ServeReport::new();
        r.record_request(10, 5, Duration::from_millis(100));
        r.record_request(20, 5, Duration::from_millis(200));
        r.wall_s = 2.0;
        assert_eq!(r.throughput_tok_s(), 15.0);
        assert_eq!(r.mean_tau(), 3.0);
        let j = r.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize().unwrap(), 2);
    }
}
