//! Collation: pack ragged per-sequence step plans into one padded
//! `[batch, tree_len]` device layout, and split the batched outputs
//! back into per-sequence rows.
//!
//! Padding follows the single-sequence `Runtime::forward` conventions:
//! pad *columns* (a real row shorter than the tree-length bucket) and
//! pad *rows* (batch slots beyond the real sequences) both mask their
//! bias fully and route their KV writes to the reserved trash slot
//! `kv - 1`, which generation never commits (the kv-cache manager caps
//! usable context at `max_ctx - RESERVED_SLOTS`, and the kv bucket
//! selector only shrinks to contexts whose trash row clears every
//! referenced slot).  Each real row carries its own cache snapshot —
//! the batched graph is a vmap of the single-sequence graph, so row `i`
//! attends only over cache plane `i`.
//!
//! ## KV-length truncation
//!
//! `kv` is the *device* context length (the `_s{kv}` graph variant the
//! caller selected); `max_ctx` is the plans'/caches' full host context.
//! When `kv < max_ctx` the collator truncates every bias row and every
//! cache plane to the first `kv` slots — under `--shared-runtime` the
//! stacked `[batch, 2L, kv, d]` cache union is the dominant transfer,
//! so this is where the upload actually shrinks.  Rows above `kv` are
//! never referenced (the selector guarantees `kv > union max slot + 1`)
//! and bias columns beyond the committed+scratch region are masked, so
//! truncation is value-exact; `collate` rejects any slot the selected
//! bucket does not cover.
//!
//! `collate` → device → `split` is a per-row identity on the real
//! (unpadded) region; `rust/tests/properties.rs` proves the round trip
//! for random tree shapes, batch sizes, and kv truncations.

use anyhow::{bail, Result};

use crate::runtime::{StepOutput, NEG_INF};

use super::BatchItem;

/// A padded batch ready for one `forward_batch` device call.
#[derive(Debug, Clone)]
pub struct CollatedBatch {
    /// real sequences in the batch (`row_lens.len()`)
    pub rows: usize,
    /// padded batch size (the `b` of the `fwd_b{b}_n{n}` bucket)
    pub batch: usize,
    /// padded tree length (the `n` of the bucket)
    pub n: usize,
    /// the plans'/caches' full host context length
    pub max_ctx: usize,
    /// the *device* context length (`kv <= max_ctx`): bias and cache
    /// are truncated to this many slots (KV-length bucketing)
    pub kv: usize,
    /// KV planes (2 × layers)
    pub planes: usize,
    pub d: usize,
    /// real token count of each row, in batch order
    pub row_lens: Vec<usize>,
    /// `[batch, n]` row-major
    pub tokens: Vec<i32>,
    /// `[batch, n]`
    pub pos: Vec<i32>,
    /// `[batch, n]` — pad entries point at the trash slot `kv - 1`
    pub slots: Vec<i32>,
    /// `[batch, n, kv]` — pad entries fully masked
    pub bias: Vec<f32>,
    /// `[batch, planes, kv, d]` stacked per-row cache snapshots,
    /// truncated to the selected kv bucket
    pub cache: Vec<f32>,
}

/// Pack `items` into the padded `[batch, n]` layout, truncating bias
/// and cache to the `kv` device context.  `batch >= items.len()`,
/// `n >= max(plan lens)` and `kv <= max_ctx` covering every referenced
/// slot must hold (the caller picked the buckets).
pub fn collate(
    items: &[BatchItem<'_>],
    batch: usize,
    n: usize,
    planes: usize,
    max_ctx: usize,
    d: usize,
    kv: usize,
) -> Result<CollatedBatch> {
    let k = items.len();
    if k == 0 {
        bail!("collate: empty batch");
    }
    if k > batch {
        bail!("collate: {k} plans exceed batch bucket {batch}");
    }
    if kv == 0 || kv > max_ctx {
        bail!("collate: kv bucket {kv} outside (0, {max_ctx}]");
    }
    let trash = (kv - 1) as i32;
    let mut row_lens = Vec::with_capacity(k);
    let mut tokens = vec![0i32; batch * n];
    let mut pos = vec![0i32; batch * n];
    let mut slots = vec![trash; batch * n];
    let mut bias = vec![NEG_INF; batch * n * kv];
    let mut cache = vec![0.0f32; batch * planes * kv * d];

    for (i, item) in items.iter().enumerate() {
        item.plan.validate()?;
        let ni = item.plan.len();
        if ni > n {
            bail!("collate: plan of {ni} tokens exceeds tree-length bucket {n}");
        }
        if item.plan.max_ctx != max_ctx {
            bail!(
                "collate: plan context {} != batch context {max_ctx}",
                item.plan.max_ctx
            );
        }
        let (l_c, s_c, d_c) = item.cache.shape();
        if (2 * l_c, s_c, d_c) != (planes, max_ctx, d) {
            bail!(
                "collate: cache shape ({l_c},{s_c},{d_c}) incompatible with batch ({},{max_ctx},{d})",
                planes / 2
            );
        }
        row_lens.push(ni);
        let base = i * n;
        for (j, &t) in item.plan.tokens.iter().enumerate() {
            tokens[base + j] = t as i32;
        }
        for (j, &p) in item.plan.pos.iter().enumerate() {
            pos[base + j] = p as i32;
        }
        for (j, &sl) in item.plan.slots.iter().enumerate() {
            // the selected bucket must keep its trash row (kv - 1)
            // above every real write — a violation means the caller's
            // kv selection ran on a different union than this one
            if sl as usize + 1 >= kv {
                bail!("collate: slot {sl} not covered by kv bucket {kv}");
            }
            slots[base + j] = sl as i32;
        }
        // bias rows truncated from the max_ctx stride to kv columns
        for j in 0..ni {
            let dst = (base + j) * kv;
            let src = j * max_ctx;
            bias[dst..dst + kv].copy_from_slice(&item.plan.bias[src..src + kv]);
        }
        // cache planes truncated to the first kv slots, gathered
        // storage-agnostically (paged caches copy page by page)
        for p in 0..planes {
            let dst = ((i * planes) + p) * kv * d;
            item.cache.copy_plane_prefix(p, kv, &mut cache[dst..dst + kv * d]);
        }
    }

    Ok(CollatedBatch {
        rows: k,
        batch,
        n,
        max_ctx,
        kv,
        planes,
        d,
        row_lens,
        tokens,
        pos,
        slots,
        bias,
        cache,
    })
}

/// Split a batched forward's padded outputs back into per-sequence
/// [`StepOutput`]s, trimmed to each row's real token count.
///
/// Shapes (row-major flats): `logits [batch, n, vocab]`,
/// `hidden [batch, n, d]`, `new_kv [batch, planes, n, d]`.
pub fn split(
    c: &CollatedBatch,
    logits: &[f32],
    hidden: &[f32],
    new_kv: &[f32],
    vocab: usize,
) -> Result<Vec<StepOutput>> {
    let (b, n, d, planes) = (c.batch, c.n, c.d, c.planes);
    if logits.len() != b * n * vocab {
        bail!("split: logits are {} values, want {}", logits.len(), b * n * vocab);
    }
    if hidden.len() != b * n * d {
        bail!("split: hidden is {} values, want {}", hidden.len(), b * n * d);
    }
    if new_kv.len() != b * planes * n * d {
        bail!("split: new_kv is {} values, want {}", new_kv.len(), b * planes * n * d);
    }
    let mut outs = Vec::with_capacity(c.rows);
    for (i, &ni) in c.row_lens.iter().enumerate() {
        let lb = i * n * vocab;
        let hb = i * n * d;
        let mut kv = Vec::with_capacity(planes * ni * d);
        for p in 0..planes {
            let base = (i * planes + p) * n * d;
            kv.extend_from_slice(&new_kv[base..base + ni * d]);
        }
        outs.push(StepOutput {
            n: ni,
            logits: logits[lb..lb + ni * vocab].to_vec(),
            hidden: hidden[hb..hb + ni * d].to_vec(),
            new_kv: kv,
        });
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::PlanInputs;
    use crate::kvcache::HostKvCache;

    fn plan(n: usize, s: usize, tag: u32) -> PlanInputs {
        PlanInputs {
            tokens: (0..n as u32).map(|j| tag + j).collect(),
            pos: (0..n as u32).collect(),
            slots: (0..n as u32).map(|j| 3 + j).collect(),
            bias: vec![0.5; n * s],
            max_ctx: s,
        }
    }

    #[test]
    fn collate_pads_rows_and_columns() {
        let s = 16;
        let c1 = HostKvCache::new(2, s, 4);
        let c2 = HostKvCache::new(2, s, 4);
        let p1 = plan(3, s, 100);
        let p2 = plan(1, s, 200);
        let items = [
            BatchItem { plan: &p1, cache: &c1 },
            BatchItem { plan: &p2, cache: &c2 },
        ];
        let c = collate(&items, 4, 4, 4, s, 4, s).unwrap();
        assert_eq!(c.rows, 2);
        assert_eq!(c.row_lens, vec![3, 1]);
        // row 0 real tokens then pad
        assert_eq!(&c.tokens[..4], &[100, 101, 102, 0]);
        // pad column routes to the trash slot with a fully masked row
        assert_eq!(c.slots[3], (s - 1) as i32);
        assert!(c.bias[3 * s..4 * s].iter().all(|&b| b == NEG_INF));
        // pad rows (2, 3) fully masked, trash-slotted
        for r in 2..4 {
            assert!(c.slots[r * 4..(r + 1) * 4].iter().all(|&sl| sl == (s - 1) as i32));
            assert!(c.bias[r * 4 * s..(r + 1) * 4 * s].iter().all(|&b| b == NEG_INF));
        }
    }

    #[test]
    fn collate_rejects_oversized_inputs() {
        let s = 16;
        let c1 = HostKvCache::new(2, s, 4);
        let p_long = plan(5, s, 0);
        let items = [BatchItem { plan: &p_long, cache: &c1 }];
        assert!(collate(&items, 1, 4, 4, s, 4, s).is_err(), "plan longer than n bucket");
        let p = plan(2, s, 0);
        let many: Vec<BatchItem> =
            (0..3).map(|_| BatchItem { plan: &p, cache: &c1 }).collect();
        assert!(collate(&many, 2, 4, 4, s, 4, s).is_err(), "more plans than batch bucket");
        let wrong_cache = HostKvCache::new(3, s, 4);
        let items = [BatchItem { plan: &p, cache: &wrong_cache }];
        assert!(collate(&items, 1, 4, 4, s, 4, s).is_err(), "foreign cache shape");
        // kv bucket outside (0, max_ctx] or not covering a slot
        let items = [BatchItem { plan: &p, cache: &c1 }];
        assert!(collate(&items, 1, 4, 4, s, 4, 0).is_err(), "kv 0");
        assert!(collate(&items, 1, 4, 4, s, 4, s + 1).is_err(), "kv > max_ctx");
        // p's slots reach 4; kv=5 puts slot 4 on the trash row
        assert!(collate(&items, 1, 4, 4, s, 4, 5).is_err(), "slot on the trash row");
    }

    #[test]
    fn collate_truncates_bias_and_cache_to_the_kv_bucket() {
        let s = 16;
        let kv = 8;
        let d = 4;
        let mut c1 = HostKvCache::new(2, s, d);
        // committed rows carry addressable values so truncation bugs show
        let rows: Vec<f32> = (0..4 * 2 * d).map(|x| x as f32).collect();
        c1.scatter(&rows, &[0, 1]).unwrap();
        c1.commit_contiguous(2).unwrap();
        let mut p1 = plan(2, s, 100);
        // addressable bias so column truncation is checkable
        for (j, b) in p1.bias.iter_mut().enumerate() {
            *b = j as f32;
        }
        let items = [BatchItem { plan: &p1, cache: &c1 }];
        let c = collate(&items, 2, 2, 4, s, d, kv).unwrap();
        assert_eq!(c.kv, kv);
        assert_eq!(c.bias.len(), 2 * 2 * kv);
        assert_eq!(c.cache.len(), 2 * 4 * kv * d, "upload did not shrink");
        // bias row j is the first kv columns of the full row
        for j in 0..2 {
            assert_eq!(
                &c.bias[j * kv..(j + 1) * kv],
                &p1.bias[j * s..j * s + kv],
                "bias row {j}"
            );
        }
        // every cache plane is the first kv slots of the full plane
        let full = c1.as_slice();
        for p in 0..4 {
            assert_eq!(
                &c.cache[p * kv * d..(p + 1) * kv * d],
                &full[p * s * d..p * s * d + kv * d],
                "plane {p}"
            );
        }
        // pads route to the truncated trash slot, not the full one
        assert_eq!(c.slots[2], (kv - 1) as i32);
        // split is agnostic to the truncation: vocab-shaped outputs
        let vocab = 3;
        let logits: Vec<f32> = (0..c.batch * c.n * vocab).map(|x| x as f32).collect();
        let hidden: Vec<f32> = (0..c.batch * c.n * d).map(|x| x as f32).collect();
        let kv_out: Vec<f32> = (0..c.batch * 4 * c.n * d).map(|x| x as f32).collect();
        let outs = split(&c, &logits, &hidden, &kv_out, vocab).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].n, 2);
    }

    #[test]
    fn split_trims_to_row_lens() {
        let s = 16;
        let (vocab, d, planes) = (5, 4, 4);
        let c1 = HostKvCache::new(2, s, d);
        let p1 = plan(2, s, 10);
        let items = [BatchItem { plan: &p1, cache: &c1 }];
        let c = collate(&items, 2, 4, planes, s, d, s).unwrap();
        // synthesize a padded device output with addressable values
        let logits: Vec<f32> = (0..c.batch * c.n * vocab).map(|x| x as f32).collect();
        let hidden: Vec<f32> = (0..c.batch * c.n * d).map(|x| 0.5 * x as f32).collect();
        let kv: Vec<f32> = (0..c.batch * planes * c.n * d).map(|x| 2.0 * x as f32).collect();
        let outs = split(&c, &logits, &hidden, &kv, vocab).unwrap();
        assert_eq!(outs.len(), 1);
        let o = &outs[0];
        assert_eq!(o.n, 2);
        assert_eq!(o.logits.len(), 2 * vocab);
        assert_eq!(o.logits[..vocab], logits[..vocab]);
        assert_eq!(o.new_kv.len(), planes * 2 * d);
        // plane 1 rows start at the padded plane stride, trimmed to n_i
        assert_eq!(o.new_kv[2 * d], kv[c.n * d]);
    }
}
