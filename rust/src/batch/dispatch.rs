//! Shared-runtime device dispatch: ONE device queue for all workers.
//!
//! PR 3 fused every in-flight tree step *within* a worker into one
//! `forward_batch` call, but with N workers the device still saw N
//! calls per wall tick.  This module inverts the worker↔runtime
//! ownership: under `--shared-runtime` the workers stop owning a
//! `Runtime` each and instead submit their per-tick step plans to a
//! single [`DeviceDispatcher`] that owns the one runtime, coalesces
//! submissions arriving within a tick window across *all* workers into
//! one `forward_batch` over the union (picking the covering
//! `fwd_b{B}_n{N}` bucket), and routes each row's [`StepOutput`] back
//! to its submitting scheduler over a reply channel:
//!
//! ```text
//!   scheduler 0 ── plans ──┐
//!   scheduler 1 ── plans ──┤   DeviceDispatcher        ┌─ device
//!   scheduler 2 ── plans ──┼──▶ window/barrier ──────▶ │ forward_batch
//!   scheduler 3 ── plans ──┘   (1 call / wall tick)    └─ (1 queue)
//!        ▲  per-row StepOutputs via reply channels  │
//!        └──────────────────────────────────────────┘
//! ```
//!
//! Pipelined/hardware-co-designed speculative systems (SPEED,
//! arXiv:2310.12072; HADES, arXiv:2412.19925) get their throughput from
//! keeping one deep device queue full instead of many shallow ones —
//! this is that topology for the PPD serving stack.
//!
//! ## Barrier and timeout
//!
//! Schedulers `register` with the dispatcher for the duration of a busy
//! spell (≥1 fused row per tick) and deregister when they drain.  The
//! dispatcher opens a *window* on the first submission of a round and
//! flushes as soon as every registered scheduler has submitted — or
//! when the window times out, so one slow/stuck worker can never stall
//! the batch indefinitely.  Solo requests (prefill chunks, fallback
//! steps, medusa head passes from engines holding a [`SharedRuntime`])
//! are executed immediately, *inside* the collection loop, which is
//! what keeps an admitting worker from deadlocking a waiting window.
//!
//! ## Failure isolation
//!
//! A panic or error in the device executor fails every rider of that
//! one batch with an error reply — the dispatcher thread itself
//! survives, and each scheduler turns its reply into per-sequence error
//! retirements, so one poisoned batch cannot take down the worker pool.
//! Caches travel with the submission by move and are always returned in
//! the reply, error or not; only a dead dispatcher loses them, and the
//! scheduler then reconciles the pool with
//! [`crate::kvcache::SharedCachePool::forget`].

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{ArtifactPaths, ModelConfig};
use crate::kvcache::HostKvCache;
use crate::metrics::{fused_slot_label, FusedHist};
use crate::runtime::{Device, Runtime, StepOutput};
use crate::trace::{Phase, TraceTrack, Tracer, NO_REQ};
use crate::util::json::Json;
use crate::util::panic_message;

use super::collator::CollatedBatch;
use super::{union_max_slot, BatchInventory, BatchItem, BatchMeta, PlanInputs};

/// Default coalescing window: how long the dispatcher waits for the
/// remaining registered schedulers after a round's first submission.
/// The barrier usually short-circuits well before this; the window only
/// bounds the damage of a straggler.
pub const DEFAULT_WINDOW: Duration = Duration::from_millis(5);

/// Floor of the adaptive window: even a fleet whose submissions land
/// back-to-back keeps a small grace period for scheduling jitter.
const WINDOW_FLOOR: Duration = Duration::from_micros(200);

/// Safety margin the adaptive window applies over the observed p95
/// inter-submission spread.
const WINDOW_MARGIN: f64 = 2.0;

/// How many recent rounds' spreads the tuner remembers.
const WINDOW_SAMPLES: usize = 64;

/// Rounds observed before the tuner trusts its p95 over the configured
/// window.
const WINDOW_WARMUP: usize = 8;

/// p95-of-spread × margin, clamped to `[WINDOW_FLOOR, cap]` — the pure
/// core of the adaptive coalescing window.  `sorted_us` are recent
/// first-to-last submission spreads in microseconds, ascending.
fn adaptive_window(sorted_us: &[f64], cap: Duration) -> Duration {
    if sorted_us.is_empty() {
        return cap;
    }
    let p95 = crate::util::bench::quantile(sorted_us, 0.95);
    Duration::from_micros((p95 * WINDOW_MARGIN).ceil() as u64).clamp(WINDOW_FLOOR, cap)
}

/// Sizes the coalescing window from observed inter-submission spreads:
/// a fleet whose schedulers submit within ~100µs of each other gets a
/// ~200µs window instead of the fixed 5ms `DEFAULT_WINDOW`, so a
/// deregistration race or one straggler costs a fraction of the old
/// worst case.  Warm-up rounds (and an empty history) fall back to the
/// configured cap, which also stays the upper clamp.
struct WindowTuner {
    spreads: VecDeque<Duration>,
    cap: Duration,
}

impl WindowTuner {
    fn new(cap: Duration) -> Self {
        WindowTuner { spreads: VecDeque::with_capacity(WINDOW_SAMPLES), cap }
    }

    /// Record one round's first-to-last submission spread.
    fn observe(&mut self, spread: Duration) {
        if self.spreads.len() == WINDOW_SAMPLES {
            self.spreads.pop_front();
        }
        self.spreads.push_back(spread);
    }

    /// The window the next round should wait on a straggler.
    fn window(&self) -> Duration {
        if self.spreads.len() < WINDOW_WARMUP {
            return self.cap;
        }
        let mut us: Vec<f64> = self.spreads.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        us.sort_by(|a, b| a.total_cmp(b));
        adaptive_window(&us, self.cap)
    }
}

/// Lock a stats mutex, recovering from poisoning: these mutexes only
/// guard plain counter maps (always left in a consistent state), so a
/// panic elsewhere while holding one must not cascade into the
/// dispatcher thread or the metrics scrape.
fn lock_stats<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One sequence's contribution to a cross-worker fused tick: the
/// planned step plus its KV cache, moved in and returned (in order)
/// with the reply.
pub struct TickRow {
    pub plan: PlanInputs,
    pub cache: HostKvCache,
}

/// The dispatcher's answer to one scheduler's tick submission.
pub struct TickReply {
    /// the submission's rows (plans + caches), in submission order —
    /// returned even on error, so the scheduler can run its apply phase
    /// against the plan and check every cache back in
    pub rows: Vec<TickRow>,
    /// per-row outputs in submission order, or the batch-wide failure
    pub outs: Result<Vec<StepOutput>>,
    /// the fused device call's wallclock share attributed to each row
    /// (elapsed / union width)
    pub row_share_s: f64,
}

struct TickSub {
    worker: usize,
    rows: Vec<TickRow>,
    reply: mpsc::Sender<TickReply>,
}

enum DeviceRequest {
    /// one scheduler's whole tick — fused across workers within the
    /// window
    Tick(TickSub),
    /// a one-off forward (prefill chunk, per-sequence fallback step)
    /// executed immediately
    Solo {
        plan: PlanInputs,
        cache: Vec<f32>,
        reply: mpsc::Sender<Result<StepOutput>>,
    },
    /// a medusa head pass for an engine behind a [`SharedRuntime`]
    Medusa {
        hidden: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
}

/// What the dispatcher runs device work against.  [`Runtime`] is the
/// production implementation; the deterministic scheduler harness
/// injects counting mocks.  Method names are distinct from
/// [`Device`]'s so a type can implement both without call-site
/// ambiguity.
pub trait DeviceExecutor {
    fn exec_forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput>;

    /// Execute the whole (cross-worker) union in as few device calls as
    /// the backend can manage — for [`Runtime`] that is one batched HLO
    /// execution when a covering `fwd_b{B}_n{N}` bucket exists.
    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>>;

    /// [`DeviceExecutor::exec_forward_batch`] plus execution metadata
    /// (the KV context the union ran at).  The dispatcher calls this
    /// variant so kv-bucket selection lands in the live
    /// `ppd_dispatch_kv_bucket` counters; executors without KV
    /// bucketing inherit the default empty meta.
    fn exec_forward_batch_meta(
        &self,
        items: &[BatchItem<'_>],
    ) -> Result<(Vec<StepOutput>, BatchMeta)> {
        Ok((self.exec_forward_batch(items)?, BatchMeta::default()))
    }

    fn exec_medusa_heads(&self, _hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("device executor has no medusa heads"))
    }

    /// A `Send` snapshot of the executor's batched-graph inventory, if
    /// it has one — lets the pipelined dispatcher pick buckets and
    /// collate round k+1 on its collector stage while round k executes
    /// here.  `None` (the default) keeps collation inside
    /// [`DeviceExecutor::exec_forward_batch_meta`].
    fn batch_inventory(&self) -> Option<BatchInventory> {
        None
    }

    /// Execute a round the dispatcher already collated against this
    /// executor's [`DeviceExecutor::batch_inventory`].  Only reached
    /// when that inventory planned the batch, so the default is
    /// unreachable for executors that never advertise one.
    fn exec_collated(&self, _c: &CollatedBatch) -> Result<(Vec<StepOutput>, BatchMeta)> {
        Err(anyhow!("device executor cannot run pre-collated rounds"))
    }
}

impl DeviceExecutor for Runtime {
    fn exec_forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput> {
        Runtime::forward(self, tokens, pos, slots, bias, cache)
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        Runtime::forward_batch(self, items)
    }

    fn exec_forward_batch_meta(
        &self,
        items: &[BatchItem<'_>],
    ) -> Result<(Vec<StepOutput>, BatchMeta)> {
        Runtime::forward_batch_meta(self, items)
    }

    fn exec_medusa_heads(&self, hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        Runtime::medusa_heads(self, hidden)
    }

    fn batch_inventory(&self) -> Option<BatchInventory> {
        Runtime::batch_inventory(self)
    }

    fn exec_collated(&self, c: &CollatedBatch) -> Result<(Vec<StepOutput>, BatchMeta)> {
        Runtime::forward_collated(self, c)
    }
}

/// Dispatcher-side counters, shared with the coordinator for the
/// Prometheus export (`ppd_dispatch_*`).
#[derive(Debug, Default)]
pub struct DispatchStats {
    /// cross-worker fused device dispatches
    batches: AtomicU64,
    /// rows across those dispatches
    rows: AtomicU64,
    /// widest single cross-worker batch
    max_width: AtomicU64,
    /// dispatches that carried rows from more than one worker — the
    /// whole point of the shared runtime
    multi_worker_batches: AtomicU64,
    /// solo forwards served outside tick fusion (prefill, fallback)
    solo_forwards: AtomicU64,
    /// submissions currently parked in the dispatcher's channel/window
    /// (live gauge)
    queue_depth: AtomicU64,
    max_queue_depth: AtomicU64,
    /// union-width histogram (clamped into the overflow slot, never
    /// dropped — with N workers × max-inflight rows a tick easily
    /// exceeds the slot count)
    width_hist: FusedHist,
    /// fused rows attributed to their submitting worker
    rows_by_worker: Mutex<BTreeMap<usize, u64>>,
    /// fused dispatches per selected KV context (`ppd_dispatch_kv_bucket`):
    /// how often the union fit a short `_s{kv}` graph vs full context —
    /// the live view of the cache-upload win
    kv_hist: Mutex<BTreeMap<usize, u64>>,
    /// highest KV slot any union ever referenced (computed across
    /// workers before collation; bounds which kv buckets can engage)
    max_union_slot: AtomicU64,
    /// rounds fully assembled (collected + collated) while the device
    /// stage was still executing the previous round — the pipelined
    /// overlap actually happening, not just configured
    overlap_batches: AtomicU64,
    /// fused rounds whose union was collated on the collector stage
    /// (outside the executor call) rather than inside it
    overlap_precollated_batches: AtomicU64,
    /// µs spent inside fused device executions — the occupancy
    /// numerator (wallclock since dispatcher start is the denominator)
    device_busy_us: AtomicU64,
    /// current adaptive coalescing window in µs (gauge; the configured
    /// cap until the tuner warms up)
    window_us: AtomicU64,
}

impl DispatchStats {
    fn on_submit(&self) {
        let d = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_queue_depth.fetch_max(d, Ordering::Relaxed);
    }

    fn on_take(&self) {
        // saturating: a submit raced with dispatcher shutdown is benign
        let _ = self.queue_depth.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| Some(d.saturating_sub(1)),
        );
    }

    fn record_batch(&self, widths: &[(usize, usize)]) {
        let total: usize = widths.iter().map(|&(_, n)| n).sum();
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(total as u64, Ordering::Relaxed);
        self.max_width.fetch_max(total as u64, Ordering::Relaxed);
        self.width_hist.record(total);
        if widths.iter().filter(|&&(_, n)| n > 0).count() > 1 {
            self.multi_worker_batches.fetch_add(1, Ordering::Relaxed);
        }
        let mut by_worker = lock_stats(&self.rows_by_worker);
        for &(w, n) in widths {
            *by_worker.entry(w).or_insert(0) += n as u64;
        }
    }

    /// Solo forwards are counted separately and deliberately NOT added
    /// to `rows_by_worker`: that map means "fused rows planned by
    /// worker w" in BOTH topologies (the worker-owned path only ever
    /// attributes `batch_rows`), so the two stay comparable.
    fn record_solo(&self) {
        self.solo_forwards.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the KV context one fused dispatch executed at.
    fn record_kv(&self, kv: usize) {
        *lock_stats(&self.kv_hist).entry(kv).or_insert(0) += 1;
    }

    /// Record the union's max occupied slot (computed before collation).
    fn record_union_slot(&self, max_slot: usize) {
        self.max_union_slot.fetch_max(max_slot as u64, Ordering::Relaxed);
    }

    /// A round was assembled while the device executed its predecessor.
    fn record_overlap(&self) {
        self.overlap_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// A round's union was collated on the collector stage.
    fn record_precollated(&self) {
        self.overlap_precollated_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Account device-execution wallclock (occupancy numerator).
    fn add_busy(&self, us: u64) {
        self.device_busy_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Publish the window the collector is currently waiting on.
    fn set_window_us(&self, us: u64) {
        self.window_us.store(us, Ordering::Relaxed);
    }

    pub fn batches_total(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn rows_total(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    pub fn max_width(&self) -> u64 {
        self.max_width.load(Ordering::Relaxed)
    }

    pub fn multi_worker_batches_total(&self) -> u64 {
        self.multi_worker_batches.load(Ordering::Relaxed)
    }

    pub fn solo_forwards_total(&self) -> u64 {
        self.solo_forwards.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn max_queue_depth(&self) -> u64 {
        self.max_queue_depth.load(Ordering::Relaxed)
    }

    /// `(width, count)` pairs of the cross-worker width histogram.
    pub fn width_hist(&self) -> Vec<(usize, u64)> {
        self.width_hist.nonzero()
    }

    pub fn rows_by_worker(&self) -> BTreeMap<usize, u64> {
        lock_stats(&self.rows_by_worker).clone()
    }

    /// `(kv_context, count)` pairs: fused dispatches per executed KV
    /// bucket (empty until a batched executable reports its context).
    pub fn kv_hist(&self) -> BTreeMap<usize, u64> {
        lock_stats(&self.kv_hist).clone()
    }

    pub fn max_union_slot(&self) -> u64 {
        self.max_union_slot.load(Ordering::Relaxed)
    }

    pub fn overlap_batches_total(&self) -> u64 {
        self.overlap_batches.load(Ordering::Relaxed)
    }

    pub fn overlap_precollated_batches_total(&self) -> u64 {
        self.overlap_precollated_batches.load(Ordering::Relaxed)
    }

    pub fn device_busy_us_total(&self) -> u64 {
        self.device_busy_us.load(Ordering::Relaxed)
    }

    pub fn window_us(&self) -> u64 {
        self.window_us.load(Ordering::Relaxed)
    }

    /// Mean rows per cross-worker device dispatch (0 when none ran).
    pub fn mean_width(&self) -> f64 {
        let b = self.batches_total();
        if b == 0 {
            0.0
        } else {
            self.rows_total() as f64 / b as f64
        }
    }

    /// Prometheus-exposition text block (`ppd_dispatch_*` lines) —
    /// appended to [`crate::coordinator::Coordinator::metrics_text`].
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut push = |name: &str, v: u64| {
            out.push_str("ppd_dispatch_");
            out.push_str(name);
            out.push(' ');
            out.push_str(&v.to_string());
            out.push('\n');
        };
        push("batches_total", self.batches_total());
        push("rows_total", self.rows_total());
        push("max_width", self.max_width());
        push("multi_worker_batches_total", self.multi_worker_batches_total());
        push("solo_forwards_total", self.solo_forwards_total());
        push("queue_depth", self.queue_depth());
        push("max_queue_depth", self.max_queue_depth());
        push("max_union_slot", self.max_union_slot());
        push("overlap_batches_total", self.overlap_batches_total());
        push("overlap_precollated_batches_total", self.overlap_precollated_batches_total());
        push("device_busy_us_total", self.device_busy_us_total());
        push("window_us", self.window_us());
        for (w, c) in self.width_hist() {
            let label = fused_slot_label(w);
            out.push_str(&format!("ppd_dispatch_width_total{{width=\"{label}\"}} {c}\n"));
        }
        for (kv, c) in self.kv_hist() {
            out.push_str(&format!("ppd_dispatch_kv_bucket_total{{kv=\"{kv}\"}} {c}\n"));
        }
        for (w, r) in self.rows_by_worker() {
            out.push_str(&format!("ppd_dispatch_rows_by_worker{{worker=\"{w}\"}} {r}\n"));
        }
        out
    }
}

/// The scheduler-side handle: submit ticks, run solo forwards, and
/// track the barrier registration.  Clone one per worker.
#[derive(Clone)]
pub struct DispatcherHandle {
    tx: mpsc::Sender<DeviceRequest>,
    active: Arc<AtomicUsize>,
    stats: Arc<DispatchStats>,
}

impl DispatcherHandle {
    /// Join the tick barrier: the dispatcher will wait (up to its
    /// window) for this scheduler's submission each round.  Call before
    /// the first submission of a busy spell.
    pub fn register(&self) {
        self.active.fetch_add(1, Ordering::SeqCst);
    }

    /// Leave the tick barrier (busy spell over, or no fused rows this
    /// tick).  Call only between submissions, never with one pending.
    pub fn deregister(&self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
    }

    /// Schedulers currently registered at the barrier.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    pub fn stats(&self) -> Arc<DispatchStats> {
        Arc::clone(&self.stats)
    }

    /// Submit one scheduler tick's fused rows; the caches move with the
    /// submission and come back in the reply.  On a dead dispatcher the
    /// rows are handed straight back so the caller can retire its
    /// sequences and check its caches in.
    pub fn submit_tick(
        &self,
        worker: usize,
        rows: Vec<TickRow>,
    ) -> std::result::Result<mpsc::Receiver<TickReply>, Vec<TickRow>> {
        let (reply, rx) = mpsc::channel();
        self.stats.on_submit();
        match self.tx.send(DeviceRequest::Tick(TickSub { worker, rows, reply })) {
            Ok(()) => Ok(rx),
            Err(mpsc::SendError(req)) => {
                self.stats.on_take();
                match req {
                    DeviceRequest::Tick(sub) => Err(sub.rows),
                    _ => Err(Vec::new()),
                }
            }
        }
    }

    /// One blocking forward round-trip (prefill chunks, fallback steps).
    ///
    /// The cache snapshot is *copied* across the channel (the caller
    /// still holds `&mut` on its `HostKvCache`, so the move-and-return
    /// pattern tick submissions use is not available here).  That cost
    /// lands only on admission/fallback paths, never on the fused
    /// steady-state tick.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
        max_ctx: usize,
    ) -> Result<StepOutput> {
        let plan = PlanInputs {
            tokens: tokens.to_vec(),
            pos: pos.to_vec(),
            slots: slots.to_vec(),
            bias: bias.to_vec(),
            max_ctx,
        };
        let (reply, rx) = mpsc::channel();
        self.stats.on_submit();
        self.tx
            .send(DeviceRequest::Solo { plan, cache: cache.to_vec(), reply })
            .map_err(|_| {
                self.stats.on_take();
                anyhow!("device dispatcher is gone")
            })?;
        rx.recv().map_err(|_| anyhow!("device dispatcher dropped a forward"))?
    }

    /// One blocking medusa-heads round-trip.
    pub fn medusa_heads(&self, hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.stats.on_submit();
        self.tx
            .send(DeviceRequest::Medusa { hidden: hidden.to_vec(), reply })
            .map_err(|_| {
                self.stats.on_take();
                anyhow!("device dispatcher is gone")
            })?;
        rx.recv().map_err(|_| anyhow!("device dispatcher dropped a head pass"))?
    }
}

/// The dispatcher's trace attachment: the shared "dispatcher" track
/// plus the round counter that keys a round's window-wait, collate, and
/// device spans together.  The collector and device stages of the
/// pipelined topology share one of these by reference — their spans
/// interleave on the same track, which is exactly what makes the
/// overlap (collate of round k+1 inside device round k) visible in the
/// exported trace.
struct DispatchTrace {
    track: TraceTrack,
    next_round: AtomicU64,
}

impl DispatchTrace {
    fn begin_round(&self) -> u64 {
        self.next_round.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn now(&self) -> u64 {
        self.track.now_us()
    }

    fn span(&self, phase: Phase, round: u64, n: u32, start_us: u64, end_us: u64) {
        self.track.span(phase, NO_REQ, round, n, start_us, end_us);
    }
}

/// One fused round, assembled (and when the executor advertises a
/// [`BatchInventory`], already collated) away from the device call —
/// the unit the pipelined dispatcher's collector stage hands its
/// device stage.
struct PreparedRound {
    subs: Vec<TickSub>,
    /// union width (rows across all submissions)
    total: usize,
    /// `(worker, rows)` per submission, in arrival order
    widths: Vec<(usize, usize)>,
    /// highest KV slot the union references
    max_slot: usize,
    /// the padded union, packed on the preparing thread; `None` routes
    /// the round through the executor's own collation/fallback path
    collated: Option<CollatedBatch>,
    /// trace round number (0 when no tracer is attached)
    round: u64,
}

/// What the collector stage forwards to the device stage.
enum Staged {
    Round(PreparedRound),
    /// solo/medusa requests pass through; they execute between rounds
    Request(DeviceRequest),
}

/// Assemble one round: flatten widths, scan the union's max slot, and
/// — given an inventory — collate the padded batch right here, so a
/// pipelined collector does the host work while the device executes
/// the previous round.  A collation miss (lone rider, no covering
/// graph, oversize) leaves `collated` empty and the executor path
/// keeps owning the fallback policy.
fn prepare_round(subs: Vec<TickSub>, inv: Option<&BatchInventory>, round: u64) -> PreparedRound {
    let total: usize = subs.iter().map(|s| s.rows.len()).sum();
    let widths: Vec<(usize, usize)> = subs.iter().map(|s| (s.worker, s.rows.len())).collect();
    let (max_slot, collated) = {
        let items: Vec<BatchItem<'_>> = subs
            .iter()
            .flat_map(|s| s.rows.iter().map(|r| BatchItem { plan: &r.plan, cache: &r.cache }))
            .collect();
        let collated = match inv.map(|inv| inv.collate(&items)) {
            Some(Some(Ok(c))) => Some(c),
            // Some(Err): the executor path re-runs the same collation
            // and surfaces the error batch-wide — no silent divergence
            _ => None,
        };
        (union_max_slot(&items), collated)
    };
    PreparedRound { subs, total, widths, max_slot, collated, round }
}

/// The device side: owns the request queue and (in production) the one
/// `Runtime`.  Drive it with [`DeviceDispatcher::run`] on a dedicated
/// thread, or [`DeviceDispatcher::pump`] /
/// [`DeviceDispatcher::pump_pipelined`] from a single-threaded test
/// harness scripting wall ticks by hand.
pub struct DeviceDispatcher {
    rx: mpsc::Receiver<DeviceRequest>,
    active: Arc<AtomicUsize>,
    stats: Arc<DispatchStats>,
    window: Duration,
    pipelined: bool,
    trace: Option<DispatchTrace>,
}

impl DeviceDispatcher {
    pub fn stats(&self) -> Arc<DispatchStats> {
        Arc::clone(&self.stats)
    }

    /// Build a dispatcher and the handle its schedulers submit through.
    pub fn channel(window: Duration, stats: Arc<DispatchStats>) -> (DispatcherHandle, Self) {
        let (tx, rx) = mpsc::channel();
        let active = Arc::new(AtomicUsize::new(0));
        let handle =
            DispatcherHandle { tx, active: Arc::clone(&active), stats: Arc::clone(&stats) };
        (handle, DeviceDispatcher { rx, active, stats, window, pipelined: false, trace: None })
    }

    /// Switch [`DeviceDispatcher::run`] to the double-buffered
    /// collector + device topology (`--pipelined`).
    pub fn set_pipelined(&mut self, on: bool) {
        self.pipelined = on;
    }

    /// Attach the flight recorder's "dispatcher" track: every round's
    /// window-wait/collate/device spans land there (subject to the
    /// tracer's sampling gate).
    pub fn set_tracer(&mut self, tracer: &Arc<Tracer>) {
        self.trace = Some(DispatchTrace {
            track: tracer.track("dispatcher"),
            next_round: AtomicU64::new(0),
        });
    }

    /// Serve until every [`DispatcherHandle`] clone is dropped (i.e. the
    /// worker pool drained).
    pub fn run(self, exec: &dyn DeviceExecutor) {
        if self.pipelined {
            return self.run_pipelined(exec);
        }
        loop {
            match self.rx.recv() {
                Err(_) => return,
                Ok(DeviceRequest::Tick(sub)) => {
                    self.stats.on_take();
                    let trace = self.trace.as_ref();
                    let round = trace.map_or(0, |t| t.begin_round());
                    let w0 = trace.map(|t| t.now());
                    let subs = self.collect(sub, exec);
                    if let (Some(t), Some(w0)) = (trace, w0) {
                        t.span(Phase::WindowWait, round, subs.len() as u32, w0, t.now());
                    }
                    self.flush_ticks(subs, exec, round);
                }
                Ok(other) => {
                    self.stats.on_take();
                    self.serve_solo(other, exec);
                }
            }
        }
    }

    /// The double-buffered serve loop: a *collector* thread owns the
    /// request queue — barriers/windows each round, forwards solos, and
    /// collates round k+1's union against the executor's
    /// [`BatchInventory`] — while THIS thread (which owns the
    /// non-`Send` executor) drains a depth-1 staging channel and runs
    /// the device calls.  Round k+1's host work (queue drain, width
    /// scan, padded collation) overlaps round k's device execution:
    ///
    /// ```text
    ///   workers ──ticks/solos──▶ collector ──PreparedRound──▶ device
    ///                            (window,      (depth-1        (exec,
    ///                             collate)      buffer)         reply)
    /// ```
    ///
    /// The coalescing window adapts per round: p95 of recent
    /// first-to-last submission spreads × margin, clamped to the
    /// configured window ([`WindowTuner`]).  Shutdown stays lossless —
    /// when the last handle drops, the collector flushes what it
    /// holds, closes the staging channel, and this thread drains every
    /// staged round before returning, so a round in *each* buffer
    /// still gets its replies.
    fn run_pipelined(self, exec: &dyn DeviceExecutor) {
        let DeviceDispatcher { rx, active, stats, window, trace, .. } = self;
        let inv = exec.batch_inventory();
        let busy = Arc::new(AtomicBool::new(false));
        let (staged_tx, staged_rx) = mpsc::sync_channel::<Staged>(1);
        std::thread::scope(|scope| {
            let c_stats = Arc::clone(&stats);
            let c_busy = Arc::clone(&busy);
            // the collector and device stages share the one "dispatcher"
            // track by reference: their spans interleave there, keyed by
            // the round counter, which is what makes the overlap visible
            let c_trace = trace.as_ref();
            scope.spawn(move || {
                let mut tuner = WindowTuner::new(window);
                loop {
                    let first = match rx.recv() {
                        Err(_) => break,
                        Ok(DeviceRequest::Tick(sub)) => {
                            c_stats.on_take();
                            sub
                        }
                        Ok(other) => {
                            c_stats.on_take();
                            if staged_tx.send(Staged::Request(other)).is_err() {
                                break;
                            }
                            continue;
                        }
                    };
                    let round_id = c_trace.map_or(0, |t| t.begin_round());
                    let w0 = c_trace.map(|t| t.now());
                    let round_window = tuner.window();
                    c_stats.set_window_us(round_window.as_micros() as u64);
                    let t0 = Instant::now();
                    let mut last_sub = t0;
                    let deadline = t0 + round_window;
                    let mut subs = vec![first];
                    loop {
                        if subs.len() >= active.load(Ordering::SeqCst).max(1) {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(DeviceRequest::Tick(s)) => {
                                c_stats.on_take();
                                last_sub = Instant::now();
                                subs.push(s);
                            }
                            Ok(other) => {
                                c_stats.on_take();
                                if staged_tx.send(Staged::Request(other)).is_err() {
                                    return;
                                }
                            }
                            Err(_) => break, // window expired or senders gone
                        }
                    }
                    // the spread is submission-to-submission, not
                    // first-to-timeout: a straggler that never came
                    // must not ratchet the window back up to the cap
                    tuner.observe(last_sub - t0);
                    let c0 = c_trace.map(|t| t.now());
                    if let (Some(t), Some(w0), Some(c0)) = (c_trace, w0, c0) {
                        t.span(Phase::WindowWait, round_id, subs.len() as u32, w0, c0);
                    }
                    let round = prepare_round(subs, inv.as_ref(), round_id);
                    if let (Some(t), Some(c0)) = (c_trace, c0) {
                        t.span(Phase::Collate, round_id, round.total as u32, c0, t.now());
                    }
                    if c_busy.load(Ordering::Relaxed) {
                        // assembled while the device stage still ran
                        // the previous round: the overlap is real
                        c_stats.record_overlap();
                    }
                    if staged_tx.send(Staged::Round(round)).is_err() {
                        break;
                    }
                }
                // rx disconnected: dropping staged_tx lets the device
                // stage drain what is buffered and exit
            });
            for staged in staged_rx.iter() {
                match staged {
                    Staged::Request(req) => {
                        Self::serve_solo_with(&stats, trace.as_ref(), req, exec);
                    }
                    Staged::Round(round) => {
                        busy.store(true, Ordering::Relaxed);
                        Self::exec_round_with(&stats, trace.as_ref(), round, exec);
                        busy.store(false, Ordering::Relaxed);
                    }
                }
            }
        });
    }

    /// Gather one round: wait until every registered scheduler has
    /// submitted or the window times out, serving solo requests
    /// immediately so an admitting worker can't wedge the barrier.
    fn collect(&self, first: TickSub, exec: &dyn DeviceExecutor) -> Vec<TickSub> {
        let mut subs = vec![first];
        let deadline = Instant::now() + self.window;
        loop {
            if subs.len() >= self.active.load(Ordering::SeqCst).max(1) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(DeviceRequest::Tick(s)) => {
                    self.stats.on_take();
                    subs.push(s);
                }
                Ok(other) => {
                    self.stats.on_take();
                    self.serve_solo(other, exec);
                }
                Err(_) => break, // window expired or senders gone: flush
            }
        }
        subs
    }

    /// Drain everything currently queued and fuse every pending tick
    /// into ONE device call; returns the number of device calls issued
    /// (solos included).  The deterministic harness's "wall tick".
    pub fn pump(&self, exec: &dyn DeviceExecutor) -> usize {
        self.pump_inner(exec, false)
    }

    /// [`DeviceDispatcher::pump`] through the pipelined code path: the
    /// round is prepared (and, inventory permitting, collated) by
    /// `prepare_round` before the executor sees it — exactly what the
    /// threaded collector stage does, minus the threads, so the
    /// deterministic harness can pin the pre-collated path's outputs
    /// against the executor-collated path's.
    pub fn pump_pipelined(&self, exec: &dyn DeviceExecutor) -> usize {
        self.pump_inner(exec, true)
    }

    fn pump_inner(&self, exec: &dyn DeviceExecutor, pipelined: bool) -> usize {
        let mut calls = 0;
        let mut subs = Vec::new();
        while let Ok(req) = self.rx.try_recv() {
            self.stats.on_take();
            match req {
                DeviceRequest::Tick(s) => subs.push(s),
                other => calls += self.serve_solo(other, exec),
            }
        }
        if !subs.is_empty() {
            let inv = if pipelined { exec.batch_inventory() } else { None };
            let round = self.trace.as_ref().map_or(0, |t| t.begin_round());
            calls += Self::exec_round_with(
                &self.stats,
                self.trace.as_ref(),
                prepare_round(subs, inv.as_ref(), round),
                exec,
            );
        }
        calls
    }

    fn serve_solo(&self, req: DeviceRequest, exec: &dyn DeviceExecutor) -> usize {
        Self::serve_solo_with(&self.stats, self.trace.as_ref(), req, exec)
    }

    fn serve_solo_with(
        stats: &DispatchStats,
        trace: Option<&DispatchTrace>,
        req: DeviceRequest,
        exec: &dyn DeviceExecutor,
    ) -> usize {
        match req {
            DeviceRequest::Solo { plan, cache, reply } => {
                stats.record_solo();
                let s0 = trace.map(|t| t.now());
                let r = catch_unwind(AssertUnwindSafe(|| {
                    exec.exec_forward(&plan.tokens, &plan.pos, &plan.slots, &plan.bias, &cache)
                }));
                let r = match r {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!("device executor panicked: {}", panic_message(p))),
                };
                if let (Some(t), Some(s0)) = (trace, s0) {
                    t.span(Phase::Solo, 0, 1, s0, t.now());
                }
                let _ = reply.send(r);
                1
            }
            DeviceRequest::Medusa { hidden, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| exec.exec_medusa_heads(&hidden)));
                let r = match r {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!("device executor panicked: {}", panic_message(p))),
                };
                let _ = reply.send(r);
                1
            }
            // defensive: a tick routed here fuses alone
            DeviceRequest::Tick(sub) => {
                let round = trace.map_or(0, |t| t.begin_round());
                Self::exec_round_with(stats, trace, prepare_round(vec![sub], None, round), exec)
            }
        }
    }

    /// Fuse one round's submissions into a single `forward_batch` over
    /// the union and route each slice (plus its caches) back.  Failure
    /// is batch-wide but dispatcher-local: every rider gets the error,
    /// the thread survives.
    fn flush_ticks(&self, subs: Vec<TickSub>, exec: &dyn DeviceExecutor, round: u64) -> usize {
        let prepared = prepare_round(subs, None, round);
        Self::exec_round_with(&self.stats, self.trace.as_ref(), prepared, exec)
    }

    /// Execute one prepared round: the device half of a fused tick,
    /// shared by the unpipelined loop, the pipelined device stage, and
    /// the scripted pumps.  When the round carries a pre-collated
    /// union, the executor runs it directly ([`DeviceExecutor::
    /// exec_collated`]); otherwise it collates internally.
    fn exec_round_with(
        stats: &DispatchStats,
        trace: Option<&DispatchTrace>,
        round: PreparedRound,
        exec: &dyn DeviceExecutor,
    ) -> usize {
        let PreparedRound { subs, total, widths, max_slot, collated, round: round_id } = round;
        if total == 0 {
            for s in subs {
                let _ = s.reply.send(TickReply {
                    rows: Vec::new(),
                    outs: Ok(Vec::new()),
                    row_share_s: 0.0,
                });
            }
            return 0;
        }
        stats.record_batch(&widths);
        // the union max-slot is a cross-WORKER property: computed over
        // every rider before collation — it is what the kv-bucket
        // selection keys off, and what bounds how small the stacked
        // cache upload can get this tick
        stats.record_union_slot(max_slot);

        let d0 = trace.map(|t| t.now());
        let t0 = Instant::now();
        let result = match &collated {
            Some(c) => {
                stats.record_precollated();
                catch_unwind(AssertUnwindSafe(|| exec.exec_collated(c)))
            }
            None => {
                let items: Vec<BatchItem<'_>> = subs
                    .iter()
                    .flat_map(|s| {
                        s.rows.iter().map(|r| BatchItem { plan: &r.plan, cache: &r.cache })
                    })
                    .collect();
                catch_unwind(AssertUnwindSafe(|| exec.exec_forward_batch_meta(&items)))
            }
        };
        let elapsed = t0.elapsed();
        stats.add_busy(elapsed.as_micros() as u64);
        if let (Some(t), Some(d0)) = (trace, d0) {
            t.span(Phase::Device, round_id, total as u32, d0, t.now());
        }
        let share = elapsed.as_secs_f64() / total as f64;

        match result {
            Ok(Ok((mut outs, meta))) if outs.len() == total => {
                if let Some(kv) = meta.kv {
                    stats.record_kv(kv);
                }
                for s in subs {
                    let TickSub { rows, reply, .. } = s;
                    let mine: Vec<StepOutput> = outs.drain(..rows.len()).collect();
                    let _ = reply.send(TickReply {
                        rows,
                        outs: Ok(mine),
                        row_share_s: share,
                    });
                }
            }
            other => {
                let msg = match other {
                    Ok(Ok((outs, _))) => format!(
                        "device dispatcher: executor returned {} outputs for {} rows",
                        outs.len(),
                        total
                    ),
                    Ok(Err(e)) => format!("{e:#}"),
                    Err(p) => format!("device executor panicked: {}", panic_message(p)),
                };
                for s in subs {
                    let TickSub { rows, reply, .. } = s;
                    let _ = reply.send(TickReply {
                        rows,
                        outs: Err(anyhow!("{msg}")),
                        row_share_s: 0.0,
                    });
                }
            }
        }
        1
    }
}

/// Worker-side [`Device`] over the dispatcher: in shared-runtime mode
/// the engines are built over this handle instead of a thread-local
/// `Runtime`, so every device call — prefill, fallback steps, medusa
/// heads — round-trips through the single device queue.  Metadata
/// (`ModelConfig`, medusa head count) is read from the artifact set on
/// disk so construction needs no device round-trip.
pub struct SharedRuntime {
    cfg: ModelConfig,
    worker: usize,
    handle: DispatcherHandle,
    medusa_heads_n: usize,
}

impl SharedRuntime {
    pub fn connect(
        paths: &ArtifactPaths,
        worker: usize,
        handle: DispatcherHandle,
    ) -> Result<Self> {
        let cfg = ModelConfig::load(&paths.model_dir())?;
        let mut medusa_heads_n = 0;
        if cfg.medusa && paths.medusa_hlo().exists() {
            // same convention as Runtime::load_medusa: the wk entry's
            // leading dim is the head count.  Parse strictly — a silent
            // default here would let the worker build a tree of the
            // wrong depth against the device-host's real head pass.
            let (_, manifest) = paths.medusa_weights();
            let j = Json::from_file(&manifest)?;
            // no wk entry falls back to 3, exactly like Runtime::
            // load_medusa — the two topologies must agree on the same
            // artifact set; a present-but-malformed entry is an error
            medusa_heads_n = match j
                .as_arr()?
                .iter()
                .find(|e| e.get("name").and_then(|n| n.as_str().ok()) == Some("wk"))
            {
                Some(wk) => wk
                    .req("shape")?
                    .as_arr()?
                    .first()
                    .ok_or_else(|| anyhow!("medusa wk entry has an empty shape"))?
                    .as_usize()?,
                None => 3,
            };
        }
        Ok(SharedRuntime { cfg, worker, handle, medusa_heads_n })
    }

    pub fn handle(&self) -> &DispatcherHandle {
        &self.handle
    }
}

impl Device for SharedRuntime {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput> {
        self.handle.forward(tokens, pos, slots, bias, cache, self.cfg.max_ctx)
    }

    fn forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        // clone the rows into an owned tick submission and ride the
        // cross-worker window like a scheduler tick would
        let rows: Vec<TickRow> = items
            .iter()
            .map(|it| TickRow { plan: it.plan.clone(), cache: it.cache.clone() })
            .collect();
        let rx = self
            .handle
            .submit_tick(self.worker, rows)
            .map_err(|_| anyhow!("device dispatcher is gone"))?;
        let reply = rx.recv().map_err(|_| anyhow!("device dispatcher dropped a batch"))?;
        reply.outs
    }

    fn has_medusa(&self) -> bool {
        self.medusa_heads_n > 0
    }

    fn medusa_n_heads(&self) -> usize {
        self.medusa_heads_n
    }

    fn medusa_heads(&self, hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        self.handle.medusa_heads(hidden)
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    /// Echo executor: output row i's logits carry plan i's first token,
    /// so routing mixups are visible; counts device calls.
    struct EchoExec {
        calls: AtomicU64,
        fail: bool,
    }

    impl EchoExec {
        fn new() -> Self {
            EchoExec { calls: AtomicU64::new(0), fail: false }
        }
    }

    impl DeviceExecutor for EchoExec {
        fn exec_forward(
            &self,
            tokens: &[u32],
            _pos: &[u32],
            _slots: &[u32],
            _bias: &[f32],
            _cache: &[f32],
        ) -> Result<StepOutput> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            Ok(StepOutput {
                n: 1,
                logits: vec![tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
        }

        fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if self.fail {
                return Err(anyhow!("injected device failure"));
            }
            Ok(items
                .iter()
                .map(|it| StepOutput {
                    n: 1,
                    logits: vec![it.plan.tokens[0] as f32],
                    hidden: vec![],
                    new_kv: vec![],
                })
                .collect())
        }
    }

    fn row(tag: u32) -> TickRow {
        TickRow {
            plan: PlanInputs {
                tokens: vec![tag],
                pos: vec![0],
                slots: vec![0],
                bias: vec![0.0; 8],
                max_ctx: 8,
            },
            cache: HostKvCache::new(1, 8, 2),
        }
    }

    #[test]
    fn pump_fuses_all_pending_ticks_into_one_call_and_routes_rows_back() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
        let exec = EchoExec::new();

        // three workers submit ragged ticks in one wall tick
        let rx0 = handle.submit_tick(0, vec![row(10), row(11)]).expect("dispatcher alive");
        let rx1 = handle.submit_tick(1, vec![row(20)]).expect("dispatcher alive");
        let rx2 = handle.submit_tick(2, vec![row(30), row(31), row(32)]).expect("dispatcher alive");
        assert_eq!(stats.queue_depth(), 3);

        let calls = disp.pump(&exec);
        assert_eq!(calls, 1, "all three submissions must fuse into one device call");
        assert_eq!(exec.calls.load(Ordering::Relaxed), 1);
        assert_eq!(stats.queue_depth(), 0);
        assert_eq!(stats.batches_total(), 1);
        assert_eq!(stats.rows_total(), 6);
        assert_eq!(stats.max_width(), 6);
        assert_eq!(stats.multi_worker_batches_total(), 1);
        assert_eq!(stats.rows_by_worker().get(&2), Some(&3));

        // every worker gets exactly its own rows back, in order
        let r0 = rx0.recv().expect("reply must arrive");
        let outs0 = r0.outs.expect("fused step must succeed");
        assert_eq!(outs0.len(), 2);
        assert_eq!(outs0[0].logits, vec![10.0]);
        assert_eq!(outs0[1].logits, vec![11.0]);
        assert_eq!(r0.rows.len(), 2);
        let r1 = rx1.recv().expect("reply must arrive");
        assert_eq!(r1.outs.expect("fused step must succeed")[0].logits, vec![20.0]);
        let r2 = rx2.recv().expect("reply must arrive");
        let outs2 = r2.outs.expect("fused step must succeed");
        assert_eq!(outs2[2].logits, vec![32.0]);
    }

    #[test]
    fn executor_failure_fails_every_rider_but_returns_caches() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
        let exec = EchoExec { calls: AtomicU64::new(0), fail: true };
        let rx0 = handle.submit_tick(0, vec![row(1)]).expect("dispatcher alive");
        let rx1 = handle.submit_tick(1, vec![row(2)]).expect("dispatcher alive");
        disp.pump(&exec);
        for rx in [rx0, rx1] {
            let r = rx.recv().expect("reply must arrive");
            assert_eq!(r.rows.len(), 1, "rows (and caches) must come back even on failure");
            assert!(format!("{:#}", r.outs.unwrap_err()).contains("injected"));
        }
    }

    #[test]
    fn dead_dispatcher_returns_rows_to_the_submitter() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
        drop(disp);
        let rows = handle.submit_tick(0, vec![row(1), row(2)]).unwrap_err();
        assert_eq!(rows.len(), 2, "rows (and caches) come straight back");
        assert_eq!(stats.queue_depth(), 0);
    }

    #[test]
    fn solo_requests_execute_immediately() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
        let done = std::thread::spawn(move || disp.run(&EchoExec::new()));
        let out = handle
            .forward(&[42], &[0], &[0], &[0.0; 8], &[0.0; 16], 8)
            .expect("solo forward must succeed");
        assert_eq!(out.logits, vec![42.0]);
        assert_eq!(handle.stats().solo_forwards_total(), 1);
        drop(handle);
        done.join().expect("thread must exit cleanly");
    }

    #[test]
    fn threaded_run_barriers_registered_workers_into_one_call() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(Duration::from_millis(200), stats);
        let exec_thread = std::thread::spawn(move || {
            let exec = EchoExec::new();
            disp.run(&exec);
            exec.calls.load(Ordering::Relaxed)
        });
        // two registered workers submit from separate threads; the
        // barrier must fuse them into one device call
        handle.register();
        handle.register();
        let h1 = {
            let h = handle.clone();
            std::thread::spawn(move || {
                let rx = h.submit_tick(0, vec![row(7)]).expect("dispatcher alive");
                let reply = rx.recv().expect("reply must arrive");
                reply.outs.expect("fused step must succeed")[0].logits.clone()
            })
        };
        let h2 = {
            let h = handle.clone();
            std::thread::spawn(move || {
                let rx = h.submit_tick(1, vec![row(9)]).expect("dispatcher alive");
                let reply = rx.recv().expect("reply must arrive");
                reply.outs.expect("fused step must succeed")[0].logits.clone()
            })
        };
        assert_eq!(h1.join().expect("thread must exit cleanly"), vec![7.0]);
        assert_eq!(h2.join().expect("thread must exit cleanly"), vec![9.0]);
        handle.deregister();
        handle.deregister();
        let stats = handle.stats();
        drop(handle);
        let calls = exec_thread.join().expect("thread must exit cleanly");
        assert_eq!(calls, 1, "barrier failed to fuse the two workers");
        assert_eq!(stats.multi_worker_batches_total(), 1);
    }

    #[test]
    fn oversized_width_clamps_into_overflow_histogram_slot() {
        // >16 rows in one tick (4 workers × 8 inflight reaches 32) must
        // land in the clamped overflow slot, not vanish
        let stats = Arc::new(DispatchStats::default());
        let (handle, disp) = DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
        let exec = EchoExec::new();
        let rows: Vec<TickRow> = (0..20u32).map(row).collect();
        let rx = handle.submit_tick(0, rows).expect("dispatcher alive");
        disp.pump(&exec);
        let reply = rx.recv().expect("reply must arrive");
        assert_eq!(reply.outs.expect("fused step must succeed").len(), 20);
        let hist = stats.width_hist();
        assert_eq!(hist, vec![(crate::metrics::FUSED_HIST_SLOTS, 1)]);
        assert!(stats.to_prometheus().contains("ppd_dispatch_width_total{width=\"16+\"} 1\n"));
    }

    #[test]
    fn adaptive_window_scales_with_spread_and_clamps() {
        let cap = Duration::from_millis(5);
        // empty history: fall back to the cap
        assert_eq!(adaptive_window(&[], cap), cap);
        // tight fleet: p95 of ~100µs spreads → 200µs window, not 5ms
        let tight: Vec<f64> = (0..64).map(|i| 90.0 + (i % 10) as f64).collect();
        let w = adaptive_window(&tight, cap);
        assert!(w < Duration::from_micros(250), "window {w:?} should shrink toward 2×p95");
        assert!(w >= WINDOW_FLOOR);
        // sub-floor spreads clamp up to the floor
        assert_eq!(adaptive_window(&[1.0, 2.0, 3.0], cap), WINDOW_FLOOR);
        // huge spreads clamp down to the configured cap
        assert_eq!(adaptive_window(&[50_000.0], cap), cap);
    }

    #[test]
    fn window_tuner_warms_up_then_tracks_p95() {
        let cap = Duration::from_millis(5);
        let mut t = WindowTuner::new(cap);
        for _ in 0..WINDOW_WARMUP - 1 {
            t.observe(Duration::from_micros(100));
            assert_eq!(t.window(), cap, "tuner must not trust a short history");
        }
        t.observe(Duration::from_micros(100));
        let w = t.window();
        assert!(w < cap, "after warmup the window should follow the observed spread");
        assert!(w >= WINDOW_FLOOR);
        // the ring forgets: flood with large spreads and the window
        // ratchets back toward the cap
        for _ in 0..WINDOW_SAMPLES {
            t.observe(Duration::from_millis(4));
        }
        assert_eq!(t.window(), cap);
    }

    #[test]
    fn pipelined_run_fuses_barriers_and_drains_on_shutdown() {
        let stats = Arc::new(DispatchStats::default());
        let (handle, mut disp) =
            DeviceDispatcher::channel(Duration::from_millis(200), Arc::clone(&stats));
        disp.set_pipelined(true);
        let exec_thread = std::thread::spawn(move || {
            let exec = EchoExec::new();
            disp.run(&exec);
            exec.calls.load(Ordering::Relaxed)
        });
        // a solo passes through the collector to the device stage
        let out = handle
            .forward(&[42], &[0], &[0], &[0.0; 8], &[0.0; 16], 8)
            .expect("solo forward must succeed");
        assert_eq!(out.logits, vec![42.0]);
        // two registered workers: the collector must still barrier them
        // into one fused round
        handle.register();
        handle.register();
        let h1 = {
            let h = handle.clone();
            std::thread::spawn(move || {
                let rx = h.submit_tick(0, vec![row(7)]).expect("dispatcher alive");
                rx.recv().expect("reply must arrive").outs.expect("fused step must succeed")
                    [0]
                .logits
                .clone()
            })
        };
        let h2 = {
            let h = handle.clone();
            std::thread::spawn(move || {
                let rx = h.submit_tick(1, vec![row(9)]).expect("dispatcher alive");
                rx.recv().expect("reply must arrive").outs.expect("fused step must succeed")
                    [0]
                .logits
                .clone()
            })
        };
        assert_eq!(h1.join().expect("thread must exit cleanly"), vec![7.0]);
        assert_eq!(h2.join().expect("thread must exit cleanly"), vec![9.0]);
        handle.deregister();
        handle.deregister();
        drop(handle);
        let calls = exec_thread.join().expect("dispatcher thread must exit cleanly");
        assert_eq!(calls, 2, "one solo + one fused round");
        assert_eq!(stats.batches_total(), 1);
        assert_eq!(stats.solo_forwards_total(), 1);
        assert_eq!(stats.multi_worker_batches_total(), 1);
        assert!(
            stats.to_prometheus().contains("ppd_dispatch_overlap_batches_total"),
            "pipelined counters must be exported"
        );
    }

    #[test]
    fn pipelined_shutdown_answers_a_round_in_each_buffer() {
        // a round parked in the staging buffer AND one mid-collection at
        // shutdown must both get replies: drop the handles right after
        // submitting and only then let the device stage run
        let stats = Arc::new(DispatchStats::default());
        let (handle, mut disp) =
            DeviceDispatcher::channel(Duration::from_micros(50), Arc::clone(&stats));
        disp.set_pipelined(true);
        let rx0 = handle.submit_tick(0, vec![row(1)]).expect("dispatcher alive");
        let rx1 = handle.submit_tick(0, vec![row(2)]).expect("dispatcher alive");
        let rx2 = handle.submit_tick(0, vec![row(3)]).expect("dispatcher alive");
        drop(handle);
        let exec = EchoExec::new();
        disp.run(&exec);
        for (rx, want) in [(rx0, 1.0), (rx1, 2.0), (rx2, 3.0)] {
            let reply = rx.recv().expect("shutdown must stay lossless");
            assert_eq!(reply.outs.expect("fused step must succeed")[0].logits, vec![want]);
        }
    }
}
