//! Batched step execution: fuse all in-flight tree steps into **one**
//! device call per scheduler tick.
//!
//! PR 2's step scheduler interleaves sequences but still issues one
//! `Runtime::forward` per sequence per tick — fair, but the device sees
//! N small latency-bound dispatches where it could see one.  This
//! module splits the engine step into a *plan/apply* pair so the
//! scheduler can batch the middle:
//!
//! ```text
//!   tick:  plan_step(seq_0) ┐
//!          plan_step(seq_1) ├── collate ──▶ forward_batch ──▶ split
//!          plan_step(seq_k) ┘                  (1 call)          │
//!          apply_step(seq_i, row_i)  ◀──────────────────────────┘
//! ```
//!
//! * [`BatchStepEngine`] is an **extension trait** over
//!   [`DecodeEngine`]: `plan_step` emits the tree tokens / positions /
//!   attention-bias rows one decode step wants to run, and `apply_step`
//!   consumes that step's slice of the batched output.  The defaults
//!   return [`StepPlan::Fallback`], which tells the scheduler to run
//!   the engine's monolithic `step` instead — so engines adopt fused
//!   stepping incrementally (vanilla/ppd/medusa are native; the
//!   lookup/speculative engines fall back until they grow plans).
//! * [`collator`] packs the ragged per-sequence plans into one padded
//!   `[batch, tree_len]` layout and splits the batched outputs back
//!   into per-sequence rows.
//! * `Runtime::forward_batch` executes the padded batch on a batched
//!   HLO bucket when the artifacts carry one (`fwd_b{B}_n{N}.hlo.txt`),
//!   and falls back to per-row `forward` calls otherwise — the fused
//!   scheduler stays correct on old artifact sets, it just doesn't get
//!   the dispatch amortization.
//!
//! The invariant the whole design hangs on: for a plan-native engine,
//! `step(seq, cache)` **is** `plan_step` → `forward` → `apply_step`
//! (see [`step_via_plan`]) — the fused and unfused paths share every
//! line of decode logic except the device call, which is what makes
//! fused-vs-unfused token-exactness testable and believable.

pub mod collator;
pub mod dispatch;

use anyhow::{bail, Result};

use crate::decoding::{DecodeEngine, SeqState, StepOutcome};
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput};

/// The device-facing half of one planned decode step: exactly the
/// arguments `Runtime::forward` takes, minus the cache (the scheduler
/// owns that).  `bias` is `[tokens.len(), max_ctx]` row-major.
#[derive(Debug, Clone)]
pub struct PlanInputs {
    pub tokens: Vec<u32>,
    pub pos: Vec<u32>,
    pub slots: Vec<u32>,
    pub bias: Vec<f32>,
    /// row stride of `bias` (the model's context length)
    pub max_ctx: usize,
}

impl PlanInputs {
    /// Number of tree tokens this step runs.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Shape sanity: pos/slots lengths and the bias row stride.
    pub fn validate(&self) -> Result<()> {
        let n = self.tokens.len();
        if self.pos.len() != n || self.slots.len() != n {
            bail!("plan: inconsistent input lengths");
        }
        if self.bias.len() != n * self.max_ctx {
            bail!("plan: bias is {} values, want {}", self.bias.len(), n * self.max_ctx);
        }
        Ok(())
    }
}

/// What `plan_step` decided for one sequence this tick.
pub enum StepPlan {
    /// The engine has no fused support for this step — the scheduler
    /// must run the monolithic [`DecodeEngine::step`] instead.
    Fallback,
    /// The sequence retired without needing a forward pass (EOS seen,
    /// budget filled, context exhausted).  `SeqState::finish` has
    /// already been applied.
    Finished(StepOutcome),
    /// Rows to run in this tick's fused forward.
    Forward(PlanInputs),
}

/// One sequence's contribution to a fused forward: its plan and a
/// read-only snapshot of its KV cache.
pub struct BatchItem<'a> {
    pub plan: &'a PlanInputs,
    pub cache: &'a HostKvCache,
}

/// One sequence's slice of a fused forward's result, handed to
/// `apply_step` together with the plan that produced it.
pub struct StepResult<'a> {
    pub plan: &'a PlanInputs,
    pub out: &'a StepOutput,
}

/// Extension trait over [`DecodeEngine`] for fused batched stepping.
///
/// The default impls opt out: `plan_step` returns
/// [`StepPlan::Fallback`] and the scheduler keeps calling `step` — any
/// engine becomes schedulable under `--fuse-steps` with an empty
/// `impl BatchStepEngine for X {}`.  Native engines override all three
/// methods and the contract is:
///
/// > `plan_step(seq)` → one `forward` over the plan → `apply_step`
/// > must leave `seq` and `cache` byte-identical to `step(seq)`,
/// > including RNG consumption.
pub trait BatchStepEngine: DecodeEngine {
    /// Plan one decode step for `seq` without running it.  May retire
    /// the sequence (returning [`StepPlan::Finished`]) when the step
    /// would not reach the device.
    fn plan_step(&mut self, _seq: &mut SeqState, _cache: &HostKvCache) -> Result<StepPlan> {
        Ok(StepPlan::Fallback)
    }

    /// Consume one sequence's slice of the batched output: scatter KV,
    /// verify, compact, account — everything `step` did after its
    /// forward call.
    fn apply_step(
        &mut self,
        _seq: &mut SeqState,
        _res: &StepResult<'_>,
        _cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        bail!("engine has no fused step support (plan_step returned Fallback)")
    }

    /// Execute every plan in one device call (or the closest the
    /// backend can get).  `results[i]` corresponds to `items[i]` and is
    /// trimmed to that plan's real row count.
    fn forward_batch(&mut self, _items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        bail!("engine has no fused step support (plan_step returned Fallback)")
    }
}

/// The shared unfused driver for plan-native engines: their
/// [`DecodeEngine::step`] is this function, so the per-sequence and
/// fused paths execute the same plan/apply code and can only differ in
/// how the forward pass is dispatched.
pub fn step_via_plan<E: BatchStepEngine + ?Sized>(
    rt: &dyn Device,
    engine: &mut E,
    seq: &mut SeqState,
    cache: &mut HostKvCache,
) -> Result<StepOutcome> {
    match engine.plan_step(seq, cache)? {
        StepPlan::Finished(o) => Ok(o),
        StepPlan::Fallback => bail!("plan-native engine planned Fallback"),
        StepPlan::Forward(plan) => {
            let t = std::time::Instant::now();
            let out = rt.forward(&plan.tokens, &plan.pos, &plan.slots, &plan.bias, cache.as_slice())?;
            seq.res.decode_s += t.elapsed().as_secs_f64();
            engine.apply_step(seq, &StepResult { plan: &plan, out: &out }, cache)
        }
    }
}
