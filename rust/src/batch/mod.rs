//! Batched step execution: fuse all in-flight tree steps into **one**
//! device call per scheduler tick.
//!
//! PR 2's step scheduler interleaves sequences but still issues one
//! `Runtime::forward` per sequence per tick — fair, but the device sees
//! N small latency-bound dispatches where it could see one.  This
//! module splits the engine step into a *plan/apply* pair so the
//! scheduler can batch the middle:
//!
//! ```text
//!   tick:  plan_step(seq_0) ┐
//!          plan_step(seq_1) ├── collate ──▶ forward_batch ──▶ split
//!          plan_step(seq_k) ┘                  (1 call)          │
//!          apply_step(seq_i, row_i)  ◀──────────────────────────┘
//! ```
//!
//! * [`BatchStepEngine`] is an **extension trait** over
//!   [`DecodeEngine`]: `plan_step` emits the tree tokens / positions /
//!   attention-bias rows one decode step wants to run, and `apply_step`
//!   consumes that step's slice of the batched output.  The defaults
//!   return [`StepPlan::Fallback`], which tells the scheduler to run
//!   the engine's monolithic `step` instead — so engines adopt fused
//!   stepping incrementally (vanilla/ppd/medusa are native; the
//!   lookup/speculative engines fall back until they grow plans).
//! * [`collator`] packs the ragged per-sequence plans into one padded
//!   `[batch, tree_len]` layout and splits the batched outputs back
//!   into per-sequence rows.
//! * `Runtime::forward_batch` executes the padded batch on a batched
//!   HLO bucket when the artifacts carry one (`fwd_b{B}_n{N}.hlo.txt`),
//!   and falls back to per-row `forward` calls otherwise — the fused
//!   scheduler stays correct on old artifact sets, it just doesn't get
//!   the dispatch amortization.
//!
//! The invariant the whole design hangs on: for a plan-native engine,
//! `step(seq, cache)` **is** `plan_step` → `forward` → `apply_step`
//! (see [`step_via_plan`]) — the fused and unfused paths share every
//! line of decode logic except the device call, which is what makes
//! fused-vs-unfused token-exactness testable and believable.

pub mod collator;
pub mod dispatch;

use anyhow::{bail, Result};

use crate::decoding::{DecodeEngine, SeqState, StepOutcome};
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput};

/// The device-facing half of one planned decode step: exactly the
/// arguments `Runtime::forward` takes, minus the cache (the scheduler
/// owns that).  `bias` is `[tokens.len(), max_ctx]` row-major.
#[derive(Debug, Clone)]
pub struct PlanInputs {
    pub tokens: Vec<u32>,
    pub pos: Vec<u32>,
    pub slots: Vec<u32>,
    pub bias: Vec<f32>,
    /// row stride of `bias` (the model's context length)
    pub max_ctx: usize,
}

impl PlanInputs {
    /// Number of tree tokens this step runs.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Shape sanity: pos/slots lengths and the bias row stride.
    pub fn validate(&self) -> Result<()> {
        let n = self.tokens.len();
        if self.pos.len() != n || self.slots.len() != n {
            bail!("plan: inconsistent input lengths");
        }
        if self.bias.len() != n * self.max_ctx {
            bail!("plan: bias is {} values, want {}", self.bias.len(), n * self.max_ctx);
        }
        Ok(())
    }
}

/// What `plan_step` decided for one sequence this tick.
pub enum StepPlan {
    /// The engine has no fused support for this step — the scheduler
    /// must run the monolithic [`DecodeEngine::step`] instead.
    Fallback,
    /// The sequence retired without needing a forward pass (EOS seen,
    /// budget filled, context exhausted).  `SeqState::finish` has
    /// already been applied.
    Finished(StepOutcome),
    /// Rows to run in this tick's fused forward.
    Forward(PlanInputs),
}

/// One sequence's contribution to a fused forward: its plan and a
/// read-only snapshot of its KV cache.
pub struct BatchItem<'a> {
    pub plan: &'a PlanInputs,
    pub cache: &'a HostKvCache,
}

/// How a fused batch actually executed, for observability (the
/// dispatcher folds this into `ppd_dispatch_kv_bucket` counts).
#[derive(Debug, Default, Clone, Copy)]
pub struct BatchMeta {
    /// KV context the batched executable ran at: `Some(kv)` when a
    /// `fwd_b{B}_n{N}[_s{kv}]` graph executed the union (full context
    /// reports `Some(max_ctx)`); `None` when the batch fell back to
    /// per-row forwards, which pick their own per-row contexts.
    pub kv: Option<usize>,
}

/// Highest KV slot any plan in the union references — the quantity
/// KV-length bucketing covers.  Under `--shared-runtime` the union
/// spans workers, so this is computed over the whole cross-worker batch
/// *before* collation: one long rider forces the full context for the
/// tick, all-short riders shrink the stacked cache upload for everyone.
pub fn union_max_slot(items: &[BatchItem<'_>]) -> usize {
    items
        .iter()
        .flat_map(|it| it.plan.slots.iter().copied())
        .max()
        .unwrap_or(0) as usize
}

/// Smallest compiled KV context covering `max_slot`: the bucket must
/// keep its reserved trash row (`kv - 1`) above every referenced slot,
/// hence the strict `kv > max_slot + 1`.  `available` reports whether a
/// variant at that context length actually exists (graph on disk /
/// executable loaded); selection falls back to `full_ctx` when nothing
/// shorter covers, and `disabled` (the `PPD_DISABLE_KV_BUCKETS` escape
/// hatch) forces the fallback unconditionally.
pub fn select_kv_bucket(
    kv_buckets: &[usize],
    full_ctx: usize,
    max_slot: usize,
    disabled: bool,
    available: impl Fn(usize) -> bool,
) -> usize {
    if disabled {
        return full_ctx;
    }
    kv_buckets
        .iter()
        .copied()
        .filter(|&kv| kv < full_ctx)
        .find(|&kv| kv > max_slot + 1 && available(kv))
        .unwrap_or(full_ctx)
}

/// Smallest batch bucket that fits `rows` sequences and has a graph for
/// the `n_bucket` tree length (`available(b, n_bucket)`); `None` sends
/// the caller to the per-row fallback.
pub fn select_batch_bucket(
    batch_buckets: &[usize],
    rows: usize,
    n_bucket: usize,
    available: impl Fn(usize, usize) -> bool,
) -> Option<usize> {
    batch_buckets
        .iter()
        .copied()
        .filter(|&b| b >= rows)
        .find(|&b| available(b, n_bucket))
}

/// `Send`-safe snapshot of an executor's batched-graph inventory:
/// everything the bucket selectors and the [`collator`] need, detached
/// from the (non-`Send`) runtime that owns the graphs.  The device
/// dispatcher's pipelined collector stage uses it to pick buckets and
/// pack round k+1's padded union on the host *while round k executes
/// on the device* — collation leaves the executor call and overlaps.
///
/// `kv_disabled` is latched when the snapshot is taken, so toggling
/// the `PPD_DISABLE_KV_BUCKETS` escape hatch mid-run does not reach an
/// already-running dispatcher (tests snapshot after setting it).
#[derive(Debug, Clone)]
pub struct BatchInventory {
    /// tree-length ladder (`cfg.buckets`, ascending)
    pub tree_buckets: Vec<usize>,
    /// batched-graph batch ladder (`cfg.batch_buckets`, ascending)
    pub batch_buckets: Vec<usize>,
    /// KV-context ladder candidates (`cfg.kv_buckets`, ascending)
    pub kv_buckets: Vec<usize>,
    /// `(b, n, kv)` triples with a graph in the artifact set
    pub available: std::collections::BTreeSet<(usize, usize, usize)>,
    /// KV planes (2 × layers)
    pub planes: usize,
    /// full host context length
    pub max_ctx: usize,
    /// model feature dim
    pub d: usize,
    /// the `PPD_DISABLE_KV_BUCKETS` escape hatch, latched at snapshot
    pub kv_disabled: bool,
}

impl BatchInventory {
    /// The `(b, n, kv)` bucket triple `Runtime::forward_batch_meta`
    /// would select for `items` — the same smallest-cover walks over
    /// the same ladders — or `None` when the batch must take a
    /// non-collated executor path (lone rider, oversized tree or
    /// batch, no covering graph).
    pub fn plan(&self, items: &[BatchItem<'_>]) -> Option<(usize, usize, usize)> {
        if items.len() < 2 {
            // a lone rider takes the single-sequence graph (b=2 would
            // double its cache upload) — mirror the executor's policy
            return None;
        }
        let max_n = items.iter().map(|it| it.plan.len()).max().unwrap_or(0);
        let n_bucket = self.tree_buckets.iter().copied().filter(|&b| b >= max_n).min()?;
        let b_bucket = select_batch_bucket(&self.batch_buckets, items.len(), n_bucket, |b, n| {
            self.available.contains(&(b, n, self.max_ctx))
        })?;
        let kv = select_kv_bucket(
            &self.kv_buckets,
            self.max_ctx,
            union_max_slot(items),
            self.kv_disabled,
            |kv| self.available.contains(&(b_bucket, n_bucket, kv)),
        );
        Some((b_bucket, n_bucket, kv))
    }

    /// Plan + pack: the host half of a fused round, runnable on any
    /// thread.  `None` routes the round to the executor's own
    /// `forward_batch` (which owns the fallback policy); `Some(Err)`
    /// surfaces a collation failure (a slot outside the selected
    /// bucket).
    pub fn collate(&self, items: &[BatchItem<'_>]) -> Option<Result<collator::CollatedBatch>> {
        let (b, n, kv) = self.plan(items)?;
        Some(collator::collate(items, b, n, self.planes, self.max_ctx, self.d, kv))
    }
}

/// One sequence's slice of a fused forward's result, handed to
/// `apply_step` together with the plan that produced it.
pub struct StepResult<'a> {
    pub plan: &'a PlanInputs,
    pub out: &'a StepOutput,
}

/// Extension trait over [`DecodeEngine`] for fused batched stepping.
///
/// The default impls opt out: `plan_step` returns
/// [`StepPlan::Fallback`] and the scheduler keeps calling `step` — any
/// engine becomes schedulable under `--fuse-steps` with an empty
/// `impl BatchStepEngine for X {}`.  Native engines override all three
/// methods and the contract is:
///
/// > `plan_step(seq)` → one `forward` over the plan → `apply_step`
/// > must leave `seq` and `cache` byte-identical to `step(seq)`,
/// > including RNG consumption.
pub trait BatchStepEngine: DecodeEngine {
    /// Plan one decode step for `seq` without running it.  May retire
    /// the sequence (returning [`StepPlan::Finished`]) when the step
    /// would not reach the device.
    fn plan_step(&mut self, _seq: &mut SeqState, _cache: &HostKvCache) -> Result<StepPlan> {
        Ok(StepPlan::Fallback)
    }

    /// Consume one sequence's slice of the batched output: scatter KV,
    /// verify, compact, account — everything `step` did after its
    /// forward call.
    fn apply_step(
        &mut self,
        _seq: &mut SeqState,
        _res: &StepResult<'_>,
        _cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        bail!("engine has no fused step support (plan_step returned Fallback)")
    }

    /// Execute every plan in one device call (or the closest the
    /// backend can get).  `results[i]` corresponds to `items[i]` and is
    /// trimmed to that plan's real row count.
    fn forward_batch(&mut self, _items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        bail!("engine has no fused step support (plan_step returned Fallback)")
    }
}

/// The shared unfused driver for plan-native engines: their
/// [`DecodeEngine::step`] is this function, so the per-sequence and
/// fused paths execute the same plan/apply code and can only differ in
/// how the forward pass is dispatched.
pub fn step_via_plan<E: BatchStepEngine + ?Sized>(
    rt: &dyn Device,
    engine: &mut E,
    seq: &mut SeqState,
    cache: &mut HostKvCache,
) -> Result<StepOutcome> {
    match engine.plan_step(seq, cache)? {
        StepPlan::Finished(o) => Ok(o),
        StepPlan::Fallback => bail!("plan-native engine planned Fallback"),
        StepPlan::Forward(plan) => {
            let t = std::time::Instant::now();
            let out = rt.forward(&plan.tokens, &plan.pos, &plan.slots, &plan.bias, &cache.device_snapshot())?;
            seq.res.decode_s += t.elapsed().as_secs_f64();
            engine.apply_step(seq, &StepResult { plan: &plan, out: &out }, cache)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(slots: Vec<u32>, s: usize) -> PlanInputs {
        let n = slots.len();
        PlanInputs {
            tokens: vec![1; n],
            pos: (0..n as u32).collect(),
            slots,
            bias: vec![0.0; n * s],
            max_ctx: s,
        }
    }

    #[test]
    fn union_max_slot_spans_every_item() {
        let s = 64;
        let p1 = plan(vec![3, 9], s);
        let p2 = plan(vec![40, 2], s);
        let c1 = HostKvCache::new(2, s, 4);
        let c2 = HostKvCache::new(2, s, 4);
        let items = [
            BatchItem { plan: &p1, cache: &c1 },
            BatchItem { plan: &p2, cache: &c2 },
        ];
        assert_eq!(union_max_slot(&items), 40);
        assert_eq!(union_max_slot(&[]), 0);
    }

    #[test]
    fn select_kv_picks_smallest_cover() {
        let buckets = [64, 128, 256];
        // slot 30: 64 > 31 covers, and it is the smallest
        assert_eq!(select_kv_bucket(&buckets, 512, 30, false, |_| true), 64);
        // slot 63: 64 > 64 is false (the trash row must stay clear), so 128
        assert_eq!(select_kv_bucket(&buckets, 512, 63, false, |_| true), 128);
        // slot 62 is the largest slot 64 still covers
        assert_eq!(select_kv_bucket(&buckets, 512, 62, false, |_| true), 64);
    }

    #[test]
    fn select_kv_falls_back_to_full_ctx() {
        let buckets = [64, 128, 256];
        // max slot beyond every variant: full context
        assert_eq!(select_kv_bucket(&buckets, 512, 400, false, |_| true), 512);
        // a bucket >= full_ctx in the list is never "short": full context
        assert_eq!(select_kv_bucket(&[512], 512, 4, false, |_| true), 512);
        // nothing lowered at all: full context
        assert_eq!(select_kv_bucket(&[], 512, 4, false, |_| true), 512);
    }

    #[test]
    fn select_kv_respects_disable_and_availability() {
        let buckets = [64, 128, 256];
        // PPD_DISABLE_KV_BUCKETS forces full context even when covered
        assert_eq!(select_kv_bucket(&buckets, 512, 10, true, |_| true), 512);
        // a covering bucket whose graph is missing is skipped for the
        // next size up (e.g. the batched variant was never lowered)
        assert_eq!(
            select_kv_bucket(&buckets, 512, 10, false, |kv| kv >= 128),
            128
        );
        assert_eq!(select_kv_bucket(&buckets, 512, 10, false, |_| false), 512);
    }

    #[test]
    fn inventory_plans_the_executor_selection() {
        let s = 64;
        let inv = BatchInventory {
            tree_buckets: vec![4, 8, 16],
            batch_buckets: vec![2, 4, 8],
            kv_buckets: vec![16, 32],
            available: [(2, 8, s), (2, 8, 16), (4, 8, s)].into_iter().collect(),
            planes: 2,
            max_ctx: s,
            d: 4,
            kv_disabled: false,
        };
        let c1 = HostKvCache::new(1, s, 4);
        let c2 = HostKvCache::new(1, s, 4);
        // two short riders: b=2 fits, n=8 covers 5 tokens, kv=16 covers
        // slot 9 (trash row 15 stays clear)
        let p1 = plan(vec![3, 9, 1, 2, 4], s);
        let p2 = plan(vec![0, 1], s);
        let items =
            [BatchItem { plan: &p1, cache: &c1 }, BatchItem { plan: &p2, cache: &c2 }];
        assert_eq!(inv.plan(&items), Some((2, 8, 16)));
        // a long rider pushes the union past every short variant: the
        // b=2 full-context graph is selected
        let p3 = plan(vec![40], s);
        let long =
            [BatchItem { plan: &p1, cache: &c1 }, BatchItem { plan: &p3, cache: &c2 }];
        assert_eq!(inv.plan(&long), Some((2, 8, s)));
        // three riders need b=4, which only ships at full context
        let trio = [
            BatchItem { plan: &p1, cache: &c1 },
            BatchItem { plan: &p2, cache: &c2 },
            BatchItem { plan: &p2, cache: &c2 },
        ];
        assert_eq!(inv.plan(&trio), Some((4, 8, s)));
        // lone riders and oversized batches route to the executor
        assert_eq!(inv.plan(&items[..1]), None);
        let nine: Vec<BatchItem<'_>> =
            (0..9).map(|_| BatchItem { plan: &p2, cache: &c2 }).collect();
        assert_eq!(inv.plan(&nine), None);
        // collation agrees with the plan it picked
        let c = inv.collate(&items).expect("covered").expect("collates");
        assert_eq!((c.batch, c.n, c.kv, c.rows), (2, 8, 16, 2));
    }

    #[test]
    fn select_batch_picks_smallest_available_cover() {
        let bb = [1usize, 2, 4, 8];
        assert_eq!(select_batch_bucket(&bb, 3, 16, |_, _| true), Some(4));
        // exact fit wins over the next size up
        assert_eq!(select_batch_bucket(&bb, 4, 16, |_, _| true), Some(4));
        // missing graph for the small bucket: next cover is taken
        assert_eq!(select_batch_bucket(&bb, 3, 16, |b, _| b >= 8), Some(8));
        // nothing fits: per-row fallback
        assert_eq!(select_batch_bucket(&bb, 9, 16, |_, _| true), None);
        assert_eq!(select_batch_bucket(&bb, 2, 16, |_, _| false), None);
    }
}
