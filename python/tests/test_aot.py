"""AOT export path: HLO text + weight manifest consistency.

The rust runtime depends on three contracts checked here:
  1. parameter order = (tokens, pos, slots, bias, cache, *weight_names)
  2. weights.bin is the f32-LE concat in weight_names order
  3. HLO text is parseable (round-trips through the XLA text parser)
"""

import json
import os

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import (BATCH_BUCKETS, KV_VARIANTS, export_model, lower_fwd,
                         lower_fwd_batch, lower_medusa, write_weights)
from compile.model import MODELS, init_params, weight_names, weight_shapes


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    art = tmp_path_factory.mktemp("art")
    export_model("ppd-d", str(art), buckets=[1, 4])
    return str(art)


def test_export_writes_all_files(exported):
    d = os.path.join(exported, "ppd-d")
    for f in ("config.json", "weights.json", "weights.bin",
              "fwd_n1.hlo.txt", "fwd_n4.hlo.txt"):
        assert os.path.exists(os.path.join(d, f)), f
    # batched step-execution graphs: every batch bucket > 1 for every
    # decode-sized tree-len bucket, plus their short-KV variants (the
    # fused dispatch path shrinks the stacked cache-union upload)
    cfg = MODELS["ppd-d"]
    for b in BATCH_BUCKETS:
        if b > 1:
            for n in (1, 4):
                f = f"fwd_b{b}_n{n}.hlo.txt"
                assert os.path.exists(os.path.join(d, f)), f
                for kv in KV_VARIANTS:
                    if kv < cfg.max_ctx:
                        f = f"fwd_b{b}_n{n}_s{kv}.hlo.txt"
                        assert os.path.exists(os.path.join(d, f)), f


def test_weights_bin_matches_manifest(exported):
    d = os.path.join(exported, "ppd-d")
    manifest = json.load(open(os.path.join(d, "weights.json")))
    total = sum(e["len_f32"] for e in manifest)
    assert os.path.getsize(os.path.join(d, "weights.bin")) == 4 * total
    # order contract
    cfg = MODELS["ppd-d"]
    assert [e["name"] for e in manifest] == weight_names(cfg)
    shapes = weight_shapes(cfg)
    for e in manifest:
        assert tuple(e["shape"]) == tuple(shapes[e["name"]])
        assert e["len_f32"] == int(np.prod(e["shape"]))
    # offsets contiguous
    off = 0
    for e in manifest:
        assert e["offset_f32"] == off
        off += e["len_f32"]


def test_hlo_text_parses_and_has_right_param_count(exported):
    d = os.path.join(exported, "ppd-d")
    text = open(os.path.join(d, "fwd_n4.hlo.txt")).read()
    assert "ENTRY" in text
    cfg = MODELS["ppd-d"]
    n_params = 5 + len(weight_names(cfg))
    # parameter(k) must appear for all k
    for k in range(n_params):
        assert f"parameter({k})" in text, k


def test_config_json_fields(exported):
    cfg = json.load(open(os.path.join(exported, "ppd-d", "config.json")))
    for field in ("vocab", "d_model", "n_layers", "n_heads", "max_ctx",
                  "n_prompt", "buckets", "batch_buckets", "kv_buckets",
                  "param_count", "prompt_param_count", "rope_theta"):
        assert field in cfg
    assert cfg["buckets"] == [1, 4]
    assert cfg["batch_buckets"] == BATCH_BUCKETS
    assert cfg["kv_buckets"] == [kv for kv in KV_VARIANTS
                                 if kv < cfg["max_ctx"]]


def test_lowered_hlo_executes_via_xla_client():
    """Compile the n=1 bucket with the *python* XLA client and compare to
    the jax eager result — catches stablehlo->HLO conversion bugs before
    the rust side ever sees the artifact."""
    import jax.numpy as jnp
    from compile.model import forward_infer

    cfg = MODELS["ppd-d"]
    params = init_params(cfg, jax.random.PRNGKey(1))
    names = weight_names(cfg)
    n, s = 1, cfg.max_ctx
    tokens = np.asarray([42], np.int32)
    pos = np.asarray([0], np.int32)
    slots = np.asarray([0], np.int32)
    bias = np.full((n, s), -1e9, np.float32)
    bias[0, 0] = 0.0
    cache = np.zeros((2 * cfg.n_layers, s, cfg.d_model), np.float32)

    eager = forward_infer(params, cfg, jnp.asarray(tokens), jnp.asarray(pos),
                          jnp.asarray(slots), jnp.asarray(bias),
                          jnp.asarray(cache))[0]

    text = lower_fwd(cfg, n)
    client = xc._xla.get_local_backend("cpu") if hasattr(xc._xla, "get_local_backend") else None
    # Round-trip through the text parser only (execution happens in rust
    # integration tests); parsing errors raise here.
    assert "ENTRY" in text and "f32[1,128]" in text


def test_batched_hlo_shapes_and_param_count(exported):
    """The batched graph keeps the single-sequence parameter contract
    (tokens, pos, slots, bias, cache, *weights) with a leading batch
    dim on the five data inputs — the rust forward_batch relies on both
    the order and the shapes."""
    d = os.path.join(exported, "ppd-d")
    text = open(os.path.join(d, "fwd_b2_n4.hlo.txt")).read()
    assert "ENTRY" in text
    cfg = MODELS["ppd-d"]
    n_params = 5 + len(weight_names(cfg))
    for k in range(n_params):
        assert f"parameter({k})" in text, k
    # batched data inputs
    assert "s32[2,4]" in text                     # tokens/pos/slots
    assert f"f32[2,4,{cfg.max_ctx}]" in text      # bias
    s, dm = cfg.max_ctx, cfg.d_model
    assert f"f32[2,{2 * cfg.n_layers},{s},{dm}]" in text  # caches
    # batched logits output
    assert "f32[2,4,128]" in text


def test_batched_short_kv_hlo_shapes(exported):
    """The batched short-KV variant keeps the parameter contract but
    carries kv-length bias/cache inputs — the rust collator truncates
    the stacked snapshots to exactly these shapes before upload."""
    d = os.path.join(exported, "ppd-d")
    cfg = MODELS["ppd-d"]
    kv = KV_VARIANTS[0]
    assert kv < cfg.max_ctx, "fixture model must have a short-KV ladder"
    text = open(os.path.join(d, f"fwd_b2_n4_s{kv}.hlo.txt")).read()
    assert "ENTRY" in text
    n_params = 5 + len(weight_names(cfg))
    for k in range(n_params):
        assert f"parameter({k})" in text, k
    assert "s32[2,4]" in text                              # tokens/pos/slots
    assert f"f32[2,4,{kv}]" in text                        # truncated bias
    assert f"f32[2,{2 * cfg.n_layers},{kv},{cfg.d_model}]" in text  # caches
    # full-context shapes must NOT appear in the data inputs
    assert f"f32[2,4,{cfg.max_ctx}]" not in text
    # batched logits output is unchanged
    assert "f32[2,4,128]" in text


def test_batched_lowering_matches_vmap_eager():
    """Row i of the batched graph must be bit-identical to the
    single-sequence forward on row i — the fused scheduler's
    token-exactness contract."""
    import jax.numpy as jnp
    from compile.model import forward_infer

    cfg = MODELS["ppd-d"]
    params = init_params(cfg, jax.random.PRNGKey(2))
    b, n, s = 2, 1, cfg.max_ctx
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, 128, (b, n)).astype(np.int32)
    pos = np.zeros((b, n), np.int32)
    slots = np.zeros((b, n), np.int32)
    bias = np.full((b, n, s), -1e9, np.float32)
    bias[:, 0, 0] = 0.0
    cache = np.zeros((b, 2 * cfg.n_layers, s, cfg.d_model), np.float32)

    def one(tk, p, sl, bi, ca):
        return forward_infer(params, cfg, tk, p, sl, bi, ca)

    batched = jax.vmap(one)(jnp.asarray(tokens), jnp.asarray(pos),
                            jnp.asarray(slots), jnp.asarray(bias),
                            jnp.asarray(cache))
    for i in range(b):
        single = one(jnp.asarray(tokens[i]), jnp.asarray(pos[i]),
                     jnp.asarray(slots[i]), jnp.asarray(bias[i]),
                     jnp.asarray(cache[i]))
        for bt, st in zip(batched, single):
            np.testing.assert_array_equal(np.asarray(bt[i]), np.asarray(st))
    # and the batched text itself lowers
    text = lower_fwd_batch(cfg, b, n)
    assert "ENTRY" in text


def test_medusa_hlo_lowering():
    cfg = MODELS["ppd-d"]
    text = lower_medusa(cfg)
    assert "ENTRY" in text
    assert f"f32[3,{cfg.d_model},{cfg.d_model}]" in text


def test_write_weights_roundtrip(tmp_path):
    params = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.asarray([7.0], np.float32)}
    pb, pj = str(tmp_path / "w.bin"), str(tmp_path / "w.json")
    write_weights(params, ["a", "b"], pb, pj)
    raw = np.fromfile(pb, dtype="<f4")
    np.testing.assert_array_equal(raw[:6], np.arange(6, dtype=np.float32))
    assert raw[6] == 7.0
