"""L2 model invariants: weight manifest, cache semantics, tree masking.

The crucial property for the whole serving stack: running tokens
incrementally through ``forward_infer`` (with the KV cache + bias built
the way the rust runtime builds it) must reproduce the batched causal
``forward_train`` logits exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (MODELS, ModelConfig, VOCAB, causal_bias,
                           forward_infer, forward_train, init_params,
                           param_count, prompt_param_count, weight_names,
                           weight_shapes)

CFG = ModelConfig(name="tiny", d_model=32, n_layers=2, n_heads=2, d_mlp=64,
                  max_ctx=128)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def _causal_prefill(params, tokens):
    """Run a prefill through forward_infer the way rust does."""
    n = len(tokens)
    s = CFG.max_ctx
    cache = jnp.zeros((2 * CFG.n_layers, s, CFG.d_model), jnp.float32)
    pos = jnp.arange(n, dtype=jnp.int32)
    slots = jnp.arange(n, dtype=jnp.int32)
    bias = np.full((n, s), -1e9, np.float32)
    for i in range(n):
        bias[i, : i + 1] = 0.0
    return forward_infer(params, CFG, jnp.asarray(tokens, jnp.int32), pos,
                         slots, jnp.asarray(bias), cache, use_pallas=False)


def test_weight_names_cover_shapes_exactly(params):
    names = weight_names(CFG)
    assert set(names) == set(weight_shapes(CFG))
    assert len(names) == len(set(names))
    for nm in names:
        assert tuple(params[nm].shape) == tuple(weight_shapes(CFG)[nm])


def test_param_count_matches_params(params):
    assert param_count(CFG) == sum(int(np.prod(p.shape))
                                   for p in params.values())
    # the paper's headline: trainable params are a vanishing fraction
    assert prompt_param_count(CFG) / param_count(CFG) < 0.01


def test_infer_matches_train_on_causal_prefill(params):
    rng = np.random.default_rng(0)
    n = 16
    tokens = rng.integers(3, VOCAB, size=n)
    logits_i, hidden_i, new_kv = _causal_prefill(params, tokens)
    logits_t = forward_train(params, CFG, jnp.asarray(tokens[None], jnp.int32),
                             jnp.arange(n, dtype=jnp.int32)[None],
                             causal_bias(1, n))
    np.testing.assert_allclose(logits_i, logits_t[0], rtol=2e-4, atol=2e-4)
    assert new_kv.shape == (2 * CFG.n_layers, n, CFG.d_model)


def test_incremental_decode_matches_prefill(params):
    """prefill(n) == prefill(n-1) then one-step decode — the rust loop."""
    rng = np.random.default_rng(1)
    n = 12
    tokens = rng.integers(3, VOCAB, size=n)
    full_logits, _, _ = _causal_prefill(params, tokens)

    # prefill first n-1, capture the cache rust would keep
    s = CFG.max_ctx
    cache = jnp.zeros((2 * CFG.n_layers, s, CFG.d_model), jnp.float32)
    pre = tokens[: n - 1]
    bias = np.full((n - 1, s), -1e9, np.float32)
    for i in range(n - 1):
        bias[i, : i + 1] = 0.0
    _, _, new_kv = forward_infer(
        params, CFG, jnp.asarray(pre, jnp.int32),
        jnp.arange(n - 1, dtype=jnp.int32),
        jnp.arange(n - 1, dtype=jnp.int32), jnp.asarray(bias), cache,
        use_pallas=False)
    # rust scatters new_kv into its host cache at the slots
    cache = cache.at[:, : n - 1, :].set(new_kv)

    # single-token decode step
    bias1 = np.full((1, s), -1e9, np.float32)
    bias1[0, : n] = 0.0  # context + self
    logits1, _, _ = forward_infer(
        params, CFG, jnp.asarray(tokens[n - 1:], jnp.int32),
        jnp.asarray([n - 1], jnp.int32), jnp.asarray([n - 1], jnp.int32),
        jnp.asarray(bias1), cache, use_pallas=False)
    np.testing.assert_allclose(logits1[0], full_logits[-1],
                               rtol=2e-4, atol=2e-4)


def test_tree_fork_isolation(params):
    """Two sibling tree branches must not see each other: the logits of a
    branch token equal those of a linear decode of its own path."""
    rng = np.random.default_rng(2)
    ctx = rng.integers(3, VOCAB, size=8)
    s = CFG.max_ctx
    cache = jnp.zeros((2 * CFG.n_layers, s, CFG.d_model), jnp.float32)
    bias = np.full((8, s), -1e9, np.float32)
    for i in range(8):
        bias[i, : i + 1] = 0.0
    _, _, kv = forward_infer(params, CFG, jnp.asarray(ctx, jnp.int32),
                             jnp.arange(8, dtype=jnp.int32),
                             jnp.arange(8, dtype=jnp.int32),
                             jnp.asarray(bias), cache, use_pallas=False)
    cache = cache.at[:, :8, :].set(kv)

    # tree: two siblings a,b at pos 8 (slots 8,9), child c of a at pos 9
    a, b, c = 10, 20, 30
    bias_t = np.full((4, s), -1e9, np.float32)
    bias_t[0, :8] = 0.0; bias_t[0, 8] = 0.0               # a: ctx+self
    bias_t[1, :8] = 0.0; bias_t[1, 9] = 0.0               # b: ctx+self
    bias_t[2, :8] = 0.0; bias_t[2, 8] = 0.0; bias_t[2, 10] = 0.0  # c: ctx+a+self
    bias_t[3, :] = -1e9  # padding row
    logits_tree, _, _ = forward_infer(
        params, CFG, jnp.asarray([a, b, c, 0], jnp.int32),
        jnp.asarray([8, 8, 9, 0], jnp.int32),
        jnp.asarray([8, 9, 10, 11], jnp.int32),
        jnp.asarray(bias_t), cache, use_pallas=False)

    # linear path ctx + a + c
    bias_l = np.full((2, s), -1e9, np.float32)
    bias_l[0, :9] = 0.0
    bias_l[1, :8] = 0.0; bias_l[1, 8:10] = 0.0
    logits_lin, _, _ = forward_infer(
        params, CFG, jnp.asarray([a, c], jnp.int32),
        jnp.asarray([8, 9], jnp.int32), jnp.asarray([8, 9], jnp.int32),
        jnp.asarray(bias_l), cache, use_pallas=False)

    np.testing.assert_allclose(logits_tree[0], logits_lin[0], rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(logits_tree[2], logits_lin[1], rtol=2e-4,
                               atol=2e-4)


def test_prompt_token_embeddings_are_used(params):
    """Ids >= VOCAB must select prompt embedding rows."""
    s = CFG.max_ctx
    cache = jnp.zeros((2 * CFG.n_layers, s, CFG.d_model), jnp.float32)
    bias = np.full((1, s), -1e9, np.float32)
    bias[0, 0] = 0.0
    args = (jnp.asarray([0], jnp.int32), jnp.asarray([0], jnp.int32),
            jnp.asarray([0], jnp.int32), jnp.asarray(bias), cache)
    l_tok, _, _ = forward_infer(params, CFG, *args, use_pallas=False)
    p2 = dict(params)
    p2["prompt_emb"] = params["prompt_emb"] + 1.0
    l_tok2, _, _ = forward_infer(p2, CFG, *args, use_pallas=False)
    np.testing.assert_allclose(l_tok, l_tok2, rtol=1e-6, atol=1e-6)

    args_p = (jnp.asarray([VOCAB], jnp.int32),) + args[1:]
    l_p, _, _ = forward_infer(params, CFG, *args_p, use_pallas=False)
    l_p2, _, _ = forward_infer(p2, CFG, *args_p, use_pallas=False)
    assert float(jnp.max(jnp.abs(l_p - l_p2))) > 1e-4


def test_pallas_and_ref_paths_agree_in_model(params):
    rng = np.random.default_rng(3)
    n = 8
    tokens = rng.integers(3, VOCAB, size=n)
    s = CFG.max_ctx
    cache = jnp.zeros((2 * CFG.n_layers, s, CFG.d_model), jnp.float32)
    bias = np.full((n, s), -1e9, np.float32)
    for i in range(n):
        bias[i, : i + 1] = 0.0
    a = forward_infer(params, CFG, jnp.asarray(tokens, jnp.int32),
                      jnp.arange(n, dtype=jnp.int32),
                      jnp.arange(n, dtype=jnp.int32), jnp.asarray(bias),
                      cache, use_pallas=False)[0]
    b = forward_infer(params, CFG, jnp.asarray(tokens, jnp.int32),
                      jnp.arange(n, dtype=jnp.int32),
                      jnp.arange(n, dtype=jnp.int32), jnp.asarray(bias),
                      cache, use_pallas=True)[0]
    np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


def test_model_zoo_configs_valid():
    for name, cfg in MODELS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.d_head % 2 == 0, name  # RoPE
        assert cfg.max_ctx % 128 == 0, name  # kernel BLOCK_KV
