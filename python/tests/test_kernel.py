"""L1 correctness: Pallas tree-attention kernel vs the pure-jnp oracle.

This is the core numerical signal for the whole stack — the AOT'd forward
graphs embed this kernel, so any mismatch here propagates to serving.
Hypothesis sweeps shapes; fixed cases pin the bucket shapes the runtime
actually uses.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import tree_attention, vmem_report

RTOL, ATOL = 2e-5, 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _rand_bias(rng, n, s, p_visible=0.6, ensure_row=True):
    m = rng.random((n, s)) < p_visible
    if ensure_row:
        m[:, 0] = True  # avoid fully-masked rows (undefined softmax)
    return jnp.where(jnp.asarray(m), 0.0, -1e9).astype(jnp.float32)


BUCKET_CASES = [
    # (n, heads, d_head, S) — shapes the AOT buckets actually use
    (1, 4, 24, 512), (2, 4, 24, 512), (4, 4, 40, 512), (8, 4, 40, 512),
    (16, 8, 28, 512), (32, 8, 28, 512), (64, 2, 32, 512), (128, 4, 40, 512),
    (256, 4, 40, 512),
]


@pytest.mark.parametrize("n,h,dh,s", BUCKET_CASES)
def test_kernel_matches_ref_buckets(n, h, dh, s):
    rng = np.random.default_rng(n * 1000 + h)
    q, k, v = _rand(rng, n, h, dh), _rand(rng, s, h, dh), _rand(rng, s, h, dh)
    bias = _rand_bias(rng, n, s)
    out = tree_attention(q, k, v, bias)
    ref = tree_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 4, 8, 16]),
    h=st.integers(1, 8),
    dh_half=st.integers(2, 24),
    s=st.sampled_from([128, 256, 512]),
    p_vis=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_sweep(n, h, dh_half, s, p_vis, seed):
    dh = 2 * dh_half  # RoPE needs even head dim; kernel supports any
    rng = np.random.default_rng(seed)
    q, k, v = _rand(rng, n, h, dh), _rand(rng, s, h, dh), _rand(rng, s, h, dh)
    bias = _rand_bias(rng, n, s, p_visible=p_vis)
    out = tree_attention(q, k, v, bias)
    ref = tree_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


def test_kernel_block_kv_sweep():
    """Perf knob must not change numerics."""
    rng = np.random.default_rng(7)
    n, h, dh, s = 16, 4, 40, 512
    q, k, v = _rand(rng, n, h, dh), _rand(rng, s, h, dh), _rand(rng, s, h, dh)
    bias = _rand_bias(rng, n, s)
    ref = tree_attention_ref(q, k, v, bias)
    for bk in (64, 128, 256, 512):
        out = tree_attention(q, k, v, bias, block_kv=bk)
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


def test_kernel_fully_masked_padding_rows_are_finite():
    """Bucket padding rows mask everything; output must stay finite."""
    rng = np.random.default_rng(11)
    n, h, dh, s = 8, 2, 16, 128
    q, k, v = _rand(rng, n, h, dh), _rand(rng, s, h, dh), _rand(rng, s, h, dh)
    bias = jnp.full((n, s), -1e9, jnp.float32)
    out = tree_attention(q, k, v, bias)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_kernel_single_visible_slot_selects_value():
    """With exactly one visible kv slot, attention returns that value."""
    rng = np.random.default_rng(13)
    n, h, dh, s = 4, 2, 8, 128
    q, k, v = _rand(rng, n, h, dh), _rand(rng, s, h, dh), _rand(rng, s, h, dh)
    bias = np.full((n, s), -1e9, np.float32)
    targets = [3, 17, 64, 127]
    for i, t in enumerate(targets):
        bias[i, t] = 0.0
    out = tree_attention(q, k, v, jnp.asarray(bias))
    for i, t in enumerate(targets):
        np.testing.assert_allclose(out[i], v[t], rtol=1e-4, atol=1e-4)


def test_vmem_report_within_tpu_budget():
    """Structural check: the largest bucket's per-step VMEM block fits a
    16 MiB TPU VMEM with generous headroom (DESIGN.md §5)."""
    for n, h, dh, s in BUCKET_CASES:
        r = vmem_report(n, s, h, dh)
        assert r["vmem_bytes"] < 4 * 1024 * 1024
        assert r["grid_steps"] >= h
