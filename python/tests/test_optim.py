"""Hand-rolled Adam + cosine schedule sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from train.optim import adam_init, adam_update, cosine_lr


def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    target = jnp.asarray([1.0, 2.0])
    opt = adam_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for i in range(200):
        g = jax.grad(loss)(params)
        params, opt = adam_update(g, opt, params, lr=0.05)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adam_state_step_increments():
    params = {"w": jnp.zeros(3)}
    opt = adam_init(params)
    g = {"w": jnp.ones(3)}
    _, opt = adam_update(g, opt, params, lr=0.1)
    _, opt = adam_update(g, opt, params, lr=0.1)
    assert int(opt.step) == 2


def test_cosine_schedule_endpoints():
    base = 0.01
    lr0 = float(cosine_lr(0, 100, base, warmup=0))
    lr_end = float(cosine_lr(100, 100, base, warmup=0))
    assert abs(lr0 - base) < 1e-9
    assert lr_end < 0.1 * base + 1e-9


def test_cosine_warmup_ramps():
    base = 0.01
    lrs = [float(cosine_lr(s, 100, base, warmup=10)) for s in range(11)]
    assert lrs[0] == 0.0
    assert all(b >= a for a, b in zip(lrs, lrs[1:]))


def test_cosine_monotone_decay_after_warmup():
    base = 3e-3
    lrs = [float(cosine_lr(s, 200, base, warmup=0)) for s in range(0, 201, 10)]
    assert all(b <= a + 1e-12 for a, b in zip(lrs, lrs[1:]))
