"""Acceptance-statistics estimator unit tests."""

import numpy as np

from train.eval_accept import TOP_R, _rank_counts


def test_rank_counts_exact_hits():
    # 3 samples, vocab 8; craft logits so truth ranks are 0, 2, and miss
    logits = np.zeros((3, 16), np.float32)
    logits[0, 5] = 10.0                       # truth 5 at rank 0
    logits[1, [1, 2, 3]] = [9.0, 8.0, 7.0]    # truth 3 at rank 2
    logits[2, 0] = 5.0                        # truth 15 far below top-10?
    logits[2, 1:11] = np.arange(10, 0, -1)    # ranks filled by ids 1..10
    truth = np.asarray([5, 3, 15])
    valid = np.ones(3, np.float32)
    d_idx = np.asarray([0, 0, 0])
    acc = np.zeros((1, TOP_R))
    tot = np.zeros(1)
    _rank_counts(logits, truth, valid, acc, tot, d_idx)
    assert tot[0] == 3
    assert acc[0, 0] == 1  # one rank-0 hit
    assert acc[0, 2] == 1  # one rank-2 hit
    assert acc[0].sum() == 2  # sample 3 missed entirely


def test_rank_counts_respects_valid_and_distance():
    logits = np.zeros((4, 8), np.float32)
    logits[:, 2] = 1.0
    truth = np.asarray([2, 2, 2, 2])
    valid = np.asarray([1, 0, 1, 1], np.float32)
    d_idx = np.asarray([0, 0, 1, 1])
    acc = np.zeros((2, TOP_R))
    tot = np.zeros(2)
    _rank_counts(logits, truth, valid, acc, tot, d_idx)
    assert tot.tolist() == [1, 2]
    assert acc[0, 0] == 1 and acc[1, 0] == 2


def test_cumulative_is_monotone():
    exact = np.asarray([[0.5, 0.2, 0.1], [0.3, 0.3, 0.1]])
    cum = np.cumsum(exact, -1)
    assert np.all(np.diff(cum, axis=-1) >= 0)
    assert np.all(cum <= 1.0 + 1e-9)
