"""Synthetic corpus generator invariants."""

import random

from train.corpus import (VOCAB_SIZE, build_corpus, decode, encode,
                          gen_chat, gen_code, gen_math)


def test_encode_decode_roundtrip_ascii():
    s = "user: hello\nassistant: calc: 1 + 2 = 3 ;"
    assert decode(encode(s)) == s


def test_all_tokens_in_vocab():
    c = build_corpus(seed=3, train_bytes=20_000, val_bytes=5_000)
    assert all(0 <= t < VOCAB_SIZE for t in c.train_ids)
    assert all(0 <= t < VOCAB_SIZE for t in c.val_ids)


def test_deterministic_given_seed():
    a = build_corpus(seed=1, train_bytes=5_000, val_bytes=1_000)
    b = build_corpus(seed=1, train_bytes=5_000, val_bytes=1_000)
    assert a.train_ids == b.train_ids
    assert a.traces["chat"][0] == b.traces["chat"][0]


def test_seeds_differ():
    a = build_corpus(seed=1, train_bytes=5_000, val_bytes=1_000)
    b = build_corpus(seed=2, train_bytes=5_000, val_bytes=1_000)
    assert a.train_ids != b.train_ids


def test_generators_produce_plausible_text():
    rng = random.Random(0)
    assert "user:" in gen_chat(rng)
    m = gen_math(rng)
    assert "calc:" in m and "=" in m
    code = gen_code(rng)
    assert code.startswith("def ") and "return" in code


def test_math_results_are_correct():
    rng = random.Random(4)
    for _ in range(20):
        line = gen_math(rng)
        for stmt in line.strip().split(";"):
            stmt = stmt.replace("calc:", "").strip()
            if not stmt:
                continue
            lhs, rhs = stmt.split("=")
            assert eval(lhs) == int(rhs), stmt


def test_traces_have_prompt_and_reference():
    c = build_corpus(seed=0, train_bytes=5_000, val_bytes=1_000,
                     trace_prompts=4)
    for task in ("chat", "math", "code"):
        assert len(c.traces[task]) == 4
        for pair in c.traces[task]:
            assert len(pair["prompt"]) > 8
            assert len(pair["reference"]) > 0


def test_val_disjoint_seeding():
    c = build_corpus(seed=0, train_bytes=5_000, val_bytes=5_000)
    assert c.train_ids[:100] != c.val_ids[:100]
