"""Invariants of the PPD training-batch construction (random insertion +
EPT ensemble masks).  If these masks are wrong, prompt training silently
leaks future tokens and the acceptance stats become meaningless —
so they are tested exhaustively on small cases.
"""

import numpy as np
import pytest

from compile.model import NEG_INF, VOCAB
from train.train_prompt import T_REAL, TrainCfg, build_prompt_batch


def _mk(tc, n_prompt=3, seed=0, b=2):
    rng = np.random.default_rng(seed)
    x = rng.integers(3, VOCAB, size=(b, T_REAL)).astype(np.int32)
    return x, build_prompt_batch(x, tc, n_prompt, rng)


def _vis(bias, a, b):
    return bias[a, b] == 0.0


@pytest.mark.parametrize("n_ept", [1, 2, 4])
def test_real_tokens_never_see_prompt_tokens(n_ept):
    tc = TrainCfg(n_ept=n_ept)
    x, nb = _mk(tc)
    kinds_real = slice(0, T_REAL)
    for bi in range(x.shape[0]):
        bias = nb["bias"][bi]
        # real rows: columns beyond the real block must be masked
        assert np.all(bias[kinds_real, T_REAL:] == NEG_INF)


def test_real_block_is_causal():
    tc = TrainCfg()
    x, nb = _mk(tc)
    bias = nb["bias"][0][:T_REAL, :T_REAL]
    vis = bias == 0.0
    assert np.array_equal(vis, np.tril(np.ones_like(vis, bool)))


def test_prompt_sees_only_its_insertion_prefix():
    tc = TrainCfg()
    x, nb = _mk(tc)
    for bi in range(x.shape[0]):
        bias, sidx, pos = nb["bias"][bi], nb["sidx"][bi], nb["pos"][bi]
        for ii in range(tc.inserts):
            for k in range(3):
                a = sidx[ii, k, 0]
                ins = pos[a] - (k + 1)  # pos = ins + k + 1
                real_vis = np.where(bias[a, :T_REAL] == 0.0)[0]
                assert real_vis.max() == ins
                assert np.array_equal(real_vis, np.arange(ins + 1))


@pytest.mark.parametrize("n_ept", [2, 3])
def test_ensemble_groups_are_isolated(n_ept):
    """EPT e of prompt k sees only EPT e of earlier prompts (same insert)."""
    tc = TrainCfg(n_ept=n_ept, mask_mode="ensemble")
    x, nb = _mk(tc)
    bias, sidx = nb["bias"][0], nb["sidx"][0]
    for ii in range(tc.inserts):
        for k in range(1, 3):
            for e in range(n_ept):
                a = sidx[ii, k, e]
                for k2 in range(k):
                    for e2 in range(n_ept):
                        expect = e2 == e
                        assert _vis(bias, a, sidx[ii, k2, e2]) == expect
                # never sees later prompts
                for k2 in range(k + 1, 3):
                    for e2 in range(n_ept):
                        assert not _vis(bias, a, sidx[ii, k2, e2])
                # never sees other insertion points' prompts
                for ii2 in range(tc.inserts):
                    if ii2 != ii:
                        assert not _vis(bias, a, sidx[ii2, 0, 0])


def test_decoder_mask_sees_all_earlier_epts():
    tc = TrainCfg(n_ept=2, mask_mode="decoder")
    x, nb = _mk(tc)
    bias, sidx = nb["bias"][0], nb["sidx"][0]
    a = sidx[0, 2, 0]
    for k2 in range(2):
        for e2 in range(2):
            assert _vis(bias, a, sidx[0, k2, e2])


def test_encoder_mask_bidirectional_within_prompt():
    tc = TrainCfg(n_ept=2, mask_mode="encoder")
    x, nb = _mk(tc)
    bias, sidx = nb["bias"][0], nb["sidx"][0]
    a0, a1 = sidx[0, 1, 0], sidx[0, 1, 1]
    assert _vis(bias, a0, a1) and _vis(bias, a1, a0)


def test_targets_align_with_distances():
    """Prompt (i, k) must target the token k+2 positions after insert i."""
    tc = TrainCfg()
    x, nb = _mk(tc, seed=5)
    for bi in range(x.shape[0]):
        pos, tgt, hard, valid = (nb["pos"][bi], nb["tgt"][bi],
                                 nb["hard"][bi], nb["valid"][bi])
        sidx = nb["sidx"][bi]
        for ii in range(tc.inserts):
            for k in range(3):
                if valid[ii, k]:
                    ins = pos[sidx[ii, k, 0]] - (k + 1)
                    assert tgt[ii, k] == ins + k + 1
                    assert hard[ii, k] == x[bi, ins + k + 2]


def test_prompt_token_ids_select_ept_rows():
    tc = TrainCfg(n_ept=2)
    x, nb = _mk(tc)
    sidx, tokens = nb["sidx"][0], nb["tokens"][0]
    for ii in range(tc.inserts):
        for k in range(3):
            for e in range(2):
                assert tokens[sidx[ii, k, e]] == VOCAB + k * 2 + e


def test_prefix_rows_visible_only_to_matching_prompt():
    tc = TrainCfg(prefix=True)
    x, nb = _mk(tc)
    bias, sidx = nb["bias"][0], nb["sidx"][0]
    n_prefix = 3
    # real rows see no prefix
    assert np.all(bias[n_prefix:n_prefix + T_REAL, :n_prefix] == NEG_INF)
    for ii in range(tc.inserts):
        for k in range(3):
            a = sidx[ii, k, 0]
            for j in range(n_prefix):
                assert _vis(bias, a, j) == (j == k)


def test_valid_masks_out_of_window_targets():
    tc = TrainCfg()
    x, nb = _mk(tc, seed=9)
    # every valid target index must be < T_REAL - 1 (teacher predicts +1)
    v = nb["valid"].astype(bool)
    assert np.all(nb["tgt"][v] < T_REAL - 1 + 3)  # prefix=0 offset
    assert np.all(nb["hard"][v] >= 0)
