"""AOT compile path: lower the L2 graphs to HLO *text* + weights.bin.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` on new jax, and
NOT serialized protos) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects.  Lowering to
stablehlo and converting through ``mlir_module_to_xla_computation`` with
``return_tuple=True`` reassigns ids and round-trips cleanly — see
/opt/xla-example/gen_hlo.py.

Outputs per model, under ``artifacts/<model>/``:

  config.json        model + bucket metadata for the rust runtime
  weights.json       ordered (name, shape, offset_f32, len_f32) manifest
  weights.bin        little-endian f32 flat dump, same order
  fwd_n<k>.hlo.txt   forward graph for each input-length bucket k
  fwd_n<k>_s<kv>.hlo.txt  short-KV-context variant of the bucket (the
                     rust runtime picks the smallest context covering
                     the referenced slots, shrinking the cache upload)
  fwd_b<b>_n<k>.hlo.txt  batched forward: b sequences x k tree tokens
                     (vmap of the single-sequence graph; the rust
                     coordinator's --fuse-steps path runs one of these
                     per scheduler tick instead of b separate forwards)
  fwd_b<b>_n<k>_s<kv>.hlo.txt  short-KV variant of the batched graph —
                     under --shared-runtime the fused tick uploads a
                     stacked [b, 2L, kv, d] cache union, so shrinking
                     kv cuts the dominant transfer by the union width
  medusa.hlo.txt     (if heads trained) hidden -> [K, V] head logits

Usage:  python -m compile.aot [--models ppd-m,...] [--out ../artifacts]
        python -m compile.aot --check   (random weights, tiny buckets)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (MODELS, ModelConfig, VOCAB, forward_infer, init_params,
                    param_count, prompt_param_count, weight_names)

BUCKETS = [1, 2, 4, 8, 16, 32, 64, 128, 256]
# Short-KV-context variants (perf pass: KV-length bucketing — the rust
# runtime picks the smallest context that covers the referenced slots,
# halving cache upload + attention compute for short contexts).  The
# same list gates the batched graphs: fwd_b<b>_n<k>_s<kv> is lowered
# for every (b, k) pair that gets a full-context batched graph, so the
# fused/shared dispatch path can shrink the stacked cache-union upload.
KV_VARIANTS = [256]
KV_VARIANT_MAX_N = 64
# Batched step-execution buckets (fused scheduling): one graph per
# (batch, tree-len) pair so a worker's whole tick runs as one device
# call.  Batch 1 is the plain fwd_n<k> graph; tree-len is capped at
# decode-step scale — prefill chunks stay single-sequence.
BATCH_BUCKETS = [1, 2, 4, 8]
BATCH_MAX_N = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fwd(cfg: ModelConfig, n: int, use_pallas: bool = True,
              max_ctx: int | None = None) -> str:
    """Lower one forward bucket.  Parameter order (the rust contract):
    tokens, pos, slots, bias, cache, then weights in weight_names order.
    ``max_ctx`` overrides the KV context length (KV-length bucketing)."""
    names = weight_names(cfg)
    s = max_ctx or cfg.max_ctx

    def fn(tokens, pos, slots, bias, cache, *weights):
        params = dict(zip(names, weights))
        return forward_infer(params, cfg, tokens, pos, slots, bias, cache,
                             use_pallas=use_pallas)

    from .model import weight_shapes
    shapes = weight_shapes(cfg)
    specs = [
        jax.ShapeDtypeStruct((n,), jnp.int32),           # tokens
        jax.ShapeDtypeStruct((n,), jnp.int32),           # pos
        jax.ShapeDtypeStruct((n,), jnp.int32),           # slots
        jax.ShapeDtypeStruct((n, s), jnp.float32),       # bias
        jax.ShapeDtypeStruct((2 * cfg.n_layers, s, cfg.d_model), jnp.float32),
    ] + [jax.ShapeDtypeStruct(shapes[nm], jnp.float32) for nm in names]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_fwd_batch(cfg: ModelConfig, b: int, n: int, use_pallas: bool = True,
                    max_ctx: int | None = None) -> str:
    """Lower one batched forward bucket: ``b`` independent sequences of
    ``n`` tree tokens, each with its own KV-cache snapshot.

    The graph is ``vmap`` of the single-sequence ``forward_infer`` with
    the weights broadcast, so row ``i`` of the batched output is
    bit-identical to running ``fwd_n<n>`` on row ``i`` alone — the
    token-exactness contract the rust fused scheduler tests rely on.
    Parameter order (the rust contract): tokens [b,n], pos [b,n],
    slots [b,n], bias [b,n,S], cache [b,2L,S,d], then weights in
    weight_names order.  Returns (logits [b,n,V], hidden [b,n,d],
    new_kv [b,2L,n,d])."""
    names = weight_names(cfg)
    s = max_ctx or cfg.max_ctx

    def fn(tokens, pos, slots, bias, cache, *weights):
        params = dict(zip(names, weights))

        def one(tk, p, sl, bi, ca):
            return forward_infer(params, cfg, tk, p, sl, bi, ca,
                                 use_pallas=use_pallas)

        return jax.vmap(one)(tokens, pos, slots, bias, cache)

    from .model import weight_shapes
    shapes = weight_shapes(cfg)
    specs = [
        jax.ShapeDtypeStruct((b, n), jnp.int32),            # tokens
        jax.ShapeDtypeStruct((b, n), jnp.int32),            # pos
        jax.ShapeDtypeStruct((b, n), jnp.int32),            # slots
        jax.ShapeDtypeStruct((b, n, s), jnp.float32),       # bias
        jax.ShapeDtypeStruct((b, 2 * cfg.n_layers, s, cfg.d_model),
                             jnp.float32),                  # caches
    ] + [jax.ShapeDtypeStruct(shapes[nm], jnp.float32) for nm in names]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_medusa(cfg: ModelConfig, n_heads: int = 3) -> str:
    """Medusa baseline heads: hidden [d] -> logits [K, V].
    Head k: logits_k = lm_head(h + silu(h @ w_k))   (Medusa-1 resblock)."""
    d = cfg.d_model

    def fn(hidden, wk, lm_head):
        h = hidden[None, :]  # [1, d]
        res = h + jax.nn.silu(jnp.einsum("bd,kde->kbe", h, wk))  # [K,1,d]
        return (jnp.einsum("kbd,dv->kbv", res, lm_head)[:, 0, :],)

    specs = [
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((n_heads, d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, VOCAB), jnp.float32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


# ---------------------------------------------------------------------------
# weights serialization (f32 LE flat dump + json manifest)
# ---------------------------------------------------------------------------


def write_weights(params: dict, names: list[str], path_bin: str, path_json: str):
    manifest, off = [], 0
    with open(path_bin, "wb") as f:
        for nm in names:
            arr = np.asarray(params[nm], dtype=np.float32)
            f.write(arr.tobytes(order="C"))
            manifest.append({"name": nm, "shape": list(arr.shape),
                             "offset_f32": off, "len_f32": int(arr.size)})
            off += int(arr.size)
    with open(path_json, "w") as f:
        json.dump(manifest, f, indent=1)


def load_trained(model: str, art: str) -> dict | None:
    path = os.path.join(art, "train", f"{model}.npz")
    if not os.path.exists(path):
        return None
    z = np.load(path)
    return {k: jnp.asarray(z[k]) for k in z.files}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def export_model(model: str, art: str, buckets=None, use_pallas=True) -> None:
    cfg = MODELS[model]
    buckets = buckets or BUCKETS
    out = os.path.join(art, model)
    os.makedirs(out, exist_ok=True)

    params = load_trained(model, art)
    trained = params is not None
    if params is None:
        print(f"[aot] {model}: no trained weights, using random init")
        params = init_params(cfg, jax.random.PRNGKey(0))
    names = weight_names(cfg)
    write_weights(params, names, os.path.join(out, "weights.bin"),
                  os.path.join(out, "weights.json"))

    for n in buckets:
        path = os.path.join(out, f"fwd_n{n}.hlo.txt")
        text = lower_fwd(cfg, n, use_pallas=use_pallas)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {model}: fwd_n{n} -> {len(text)} chars")
        for kv in KV_VARIANTS:
            if kv < cfg.max_ctx and n <= KV_VARIANT_MAX_N:
                path = os.path.join(out, f"fwd_n{n}_s{kv}.hlo.txt")
                text = lower_fwd(cfg, n, use_pallas=use_pallas, max_ctx=kv)
                with open(path, "w") as f:
                    f.write(text)
                print(f"[aot] {model}: fwd_n{n}_s{kv} -> {len(text)} chars")
        # batched step-execution variants (b=1 is the graph above),
        # each with the same short-KV ladder as the single-sequence
        # bucket so the fused/shared dispatch can shrink the stacked
        # cache-union upload
        for b in BATCH_BUCKETS:
            if b > 1 and n <= BATCH_MAX_N:
                path = os.path.join(out, f"fwd_b{b}_n{n}.hlo.txt")
                text = lower_fwd_batch(cfg, b, n, use_pallas=use_pallas)
                with open(path, "w") as f:
                    f.write(text)
                print(f"[aot] {model}: fwd_b{b}_n{n} -> {len(text)} chars")
                for kv in KV_VARIANTS:
                    if kv < cfg.max_ctx and n <= KV_VARIANT_MAX_N:
                        path = os.path.join(out, f"fwd_b{b}_n{n}_s{kv}.hlo.txt")
                        text = lower_fwd_batch(cfg, b, n, use_pallas=use_pallas,
                                               max_ctx=kv)
                        with open(path, "w") as f:
                            f.write(text)
                        print(f"[aot] {model}: fwd_b{b}_n{n}_s{kv} -> "
                              f"{len(text)} chars")

    medusa = load_trained(f"{model}-medusa", art)
    has_medusa = medusa is not None
    if has_medusa:
        with open(os.path.join(out, "medusa.hlo.txt"), "w") as f:
            f.write(lower_medusa(cfg))
        write_weights(medusa, ["wk", "lm_head"],
                      os.path.join(out, "medusa_weights.bin"),
                      os.path.join(out, "medusa_weights.json"))
        print(f"[aot] {model}: medusa heads exported")

    config = {
        "name": model, "vocab": VOCAB, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_head": cfg.d_head,
        "d_mlp": cfg.d_mlp, "max_ctx": cfg.max_ctx, "n_prompt": cfg.n_prompt,
        "n_ept": cfg.n_ept, "rope_theta": cfg.rope_theta,
        "buckets": buckets, "batch_buckets": BATCH_BUCKETS,
        "kv_buckets": [kv for kv in KV_VARIANTS if kv < cfg.max_ctx],
        "trained": trained, "medusa": has_medusa,
        "param_count": param_count(cfg),
        "prompt_param_count": prompt_param_count(cfg),
    }
    with open(os.path.join(out, "config.json"), "w") as f:
        json.dump(config, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="ppd-s,ppd-m,ppd-l,ppd-d")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--buckets", default="")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the jnp reference attention instead of "
                         "the Pallas kernel (debugging)")
    ap.add_argument("--check", action="store_true",
                    help="fast self-check: one tiny model, two buckets")
    args = ap.parse_args()

    models = args.models.split(",")
    buckets = [int(b) for b in args.buckets.split(",") if b] or None
    if args.check:
        models, buckets = ["ppd-d"], [1, 8]
    for m in models:
        export_model(m, args.out, buckets, use_pallas=not args.no_pallas)

    # v2: batched step-execution graphs (fwd_b<b>_n<k>) + batch_buckets
    # in per-model configs; the rust loader treats their absence as v1
    # and falls back to per-row forwards.  kv_buckets lists the
    # short-KV contexts both the single-sequence and batched graphs are
    # additionally lowered at (per-model configs filter to < max_ctx).
    manifest = {"models": models,
                "buckets": buckets or BUCKETS,
                "batch_buckets": BATCH_BUCKETS,
                "kv_buckets": KV_VARIANTS,
                "format": "hlo-text+f32-weights-v2"}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] done")


if __name__ == "__main__":
    main()
