"""L2: the base LM + PPD prompt tokens as a JAX compute graph.

Decoder-only byte-level transformer (RoPE, RMSNorm, SwiGLU) in the
functional style: parameters are a flat ``{name: array}`` dict with a
deterministic ordering (``weight_names``) shared with the rust runtime —
the AOT'd HLO takes the weights as trailing parameters in exactly this
order, and ``artifacts/<model>/weights.json`` records (name, shape,
offset) into ``weights.bin``.

Two forward functions:

* ``forward_infer`` — the serving graph (single sequence + KV cache +
  tree bias) that is AOT-lowered per input-length bucket.  Calls the L1
  Pallas tree-attention kernel.  Returns ``(logits, hidden, new_kv)``;
  the authoritative cache lives host-side in rust (see DESIGN.md §3).
* ``forward_train`` — batched, cache-free training graph with an
  arbitrary additive attention bias, used for base-model training,
  prompt-token (PPD) training with random insertion + EPT ensemble
  masks, and the Medusa-head baseline.

Prompt tokens are embedding rows appended after the vocab: token id
``VOCAB + j`` selects ``prompt_emb[j]``.  With ``n_ept`` ensemble prompt
tokens per prompt token, row ``k * n_ept + e`` is EPT ``e`` of prompt
token ``k``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.ref import tree_attention_ref
from .kernels.tree_attention import tree_attention

VOCAB = 128
NEG_INF = -1e9


@dataclass(frozen=True)
class ModelConfig:
    name: str = "ppd-m"
    d_model: int = 160
    n_layers: int = 4
    n_heads: int = 4
    d_mlp: int = 432          # ~2.7x, SwiGLU
    max_ctx: int = 512
    n_prompt: int = 3         # prompt tokens (token distance 1..n_prompt)
    n_ept: int = 1            # ensemble prompt tokens per prompt token
    rope_theta: float = 10000.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_prompt_rows(self) -> int:
        return self.n_prompt * self.n_ept


# The model zoo: S/M/L mirror MobileLLaMA / Vicuna-7B / Vicuna-13B roles,
# D is the Vicuna-68M-style draft model (see DESIGN.md §2).
MODELS: dict[str, ModelConfig] = {
    "ppd-s": ModelConfig(name="ppd-s", d_model=96, n_layers=2, n_heads=4, d_mlp=256),
    "ppd-m": ModelConfig(name="ppd-m", d_model=160, n_layers=4, n_heads=4, d_mlp=432),
    "ppd-l": ModelConfig(name="ppd-l", d_model=224, n_layers=6, n_heads=8, d_mlp=608),
    "ppd-d": ModelConfig(name="ppd-d", d_model=64, n_layers=2, n_heads=2, d_mlp=176),
}


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def weight_names(cfg: ModelConfig) -> list[str]:
    """Deterministic parameter order — the rust runtime relies on it."""
    names = ["tok_emb", "prompt_emb"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.attn_norm", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo",
            f"l{l}.mlp_norm", f"l{l}.w1", f"l{l}.w2", f"l{l}.w3",
        ]
    names += ["final_norm", "lm_head"]
    return names


def weight_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, dm = cfg.d_model, cfg.d_mlp
    shapes = {
        "tok_emb": (VOCAB, d),
        "prompt_emb": (cfg.n_prompt_rows, d),
        "final_norm": (d,),
        "lm_head": (d, VOCAB),
    }
    for l in range(cfg.n_layers):
        shapes.update({
            f"l{l}.attn_norm": (d,),
            f"l{l}.wq": (d, d), f"l{l}.wk": (d, d),
            f"l{l}.wv": (d, d), f"l{l}.wo": (d, d),
            f"l{l}.mlp_norm": (d,),
            f"l{l}.w1": (d, dm), f"l{l}.w2": (dm, d), f"l{l}.w3": (d, dm),
        })
    return shapes


def init_params(cfg: ModelConfig, key) -> dict:
    shapes = weight_shapes(cfg)
    params = {}
    for name in weight_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return params


def param_count(cfg: ModelConfig) -> int:
    return sum(
        int(jnp.prod(jnp.array(s))) for s in weight_shapes(cfg).values()
    )


def prompt_param_count(cfg: ModelConfig) -> int:
    return cfg.n_prompt_rows * cfg.d_model


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps: float = 1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, pos, theta: float):
    """Rotary embedding.  x [..., T, H, dh]; pos [..., T] (int32)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., T, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def embed(params, tokens):
    table = jnp.concatenate([params["tok_emb"], params["prompt_emb"]], axis=0)
    return table[tokens]


# ---------------------------------------------------------------------------
# inference graph (AOT'd): single sequence, KV cache, tree bias
# ---------------------------------------------------------------------------


def forward_infer(params, cfg: ModelConfig, tokens, pos, slots, bias, cache,
                  *, use_pallas: bool = True):
    """One decode/prefill step over ``n`` tree tokens.

    tokens i32[n]; pos i32[n]; slots i32[n] (cache write positions);
    bias f32[n, S]; cache f32[2L, S, d] (k rows at 2l, v rows at 2l+1).

    Returns (logits f32[n, V], hidden f32[n, d], new_kv f32[2L, n, d]).
    The caller owns the cache: rust scatters ``new_kv`` into its host
    copy at ``slots`` (and compacts accepted rows after verification).
    """
    n = tokens.shape[0]
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    attn_fn = tree_attention if use_pallas else tree_attention_ref

    x = embed(params, tokens)
    new_kv = []
    for l in range(cfg.n_layers):
        hn = rmsnorm(x, params[f"l{l}.attn_norm"])
        q = (hn @ params[f"l{l}.wq"]).reshape(n, h, dh)
        k = (hn @ params[f"l{l}.wk"]).reshape(n, h, dh)
        v = hn @ params[f"l{l}.wv"]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta).reshape(n, d)
        # scatter this step's K/V into the cache, then attend over it
        kc = cache[2 * l].at[slots].set(k)
        vc = cache[2 * l + 1].at[slots].set(v)
        new_kv.append(k)
        new_kv.append(v)
        attn = attn_fn(q, kc.reshape(-1, h, dh), vc.reshape(-1, h, dh), bias)
        x = x + attn.reshape(n, d) @ params[f"l{l}.wo"]
        mn = rmsnorm(x, params[f"l{l}.mlp_norm"])
        x = x + (jax.nn.silu(mn @ params[f"l{l}.w1"]) * (mn @ params[f"l{l}.w3"])) @ params[f"l{l}.w2"]
    hidden = rmsnorm(x, params["final_norm"])
    logits = hidden @ params["lm_head"]
    return logits, hidden, jnp.stack(new_kv, axis=0)


# ---------------------------------------------------------------------------
# training graph: batched, cache-free, arbitrary bias
# ---------------------------------------------------------------------------


def forward_train(params, cfg: ModelConfig, tokens, pos, bias,
                  *, return_hidden: bool = False, collect_layers: bool = False):
    """Batched forward.  tokens i32[B,T]; pos i32[B,T]; bias f32[B,T,T].

    ``collect_layers`` additionally returns the post-residual activations
    of every layer (used by the multi-exit ensemble ablation, appx B.7).
    """
    b, t = tokens.shape
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))

    layer_outs = []
    x = embed(params, tokens)
    for l in range(cfg.n_layers):
        hn = rmsnorm(x, params[f"l{l}.attn_norm"])
        q = (hn @ params[f"l{l}.wq"]).reshape(b, t, h, dh)
        k = (hn @ params[f"l{l}.wk"]).reshape(b, t, h, dh)
        v = (hn @ params[f"l{l}.wv"]).reshape(b, t, h, dh)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias[:, None]
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, t, d)
        x = x + attn @ params[f"l{l}.wo"]
        mn = rmsnorm(x, params[f"l{l}.mlp_norm"])
        x = x + (jax.nn.silu(mn @ params[f"l{l}.w1"]) * (mn @ params[f"l{l}.w3"])) @ params[f"l{l}.w2"]
        if collect_layers:
            layer_outs.append(x)
    hidden = rmsnorm(x, params["final_norm"])
    logits = hidden @ params["lm_head"]
    if collect_layers:
        return logits, hidden, layer_outs
    if return_hidden:
        return logits, hidden
    return logits


def causal_bias(b: int, t: int):
    m = jnp.where(jnp.tril(jnp.ones((t, t), jnp.float32)) > 0, 0.0, NEG_INF)
    return jnp.broadcast_to(m, (b, t, t))


# ---------------------------------------------------------------------------
# EPT / prompt-token training masks (paper §3.2, appendix B.5)
# ---------------------------------------------------------------------------


def prompt_block_bias(t_real_vis, kinds, groups, mode: str = "ensemble"):
    """Attention bias for a sequence with inserted prompt tokens.

    kinds   i32[T]: 0 = real token, 1 = prompt/EPT token
    groups  i32[T]: EPT group id for prompt tokens (-1 for real tokens)
    t_real_vis — causal visibility base [T, T] (0/1), position-causal.

    Rules (ensemble mode, the paper's choice):
      * real tokens attend only to *real* tokens (keeps the base
        distribution intact — also what makes single-forward KD valid);
      * EPT in group g attends to causally-earlier real tokens and to
        causally-earlier EPTs *of the same group*;
    decoder mode: EPTs attend to all causally-earlier tokens;
    encoder mode: additionally EPTs of the same *prompt token* see each
      other bidirectionally (groups arg then carries the prompt-token id).
    """
    t = kinds.shape[0]
    real = kinds == 0
    same_group = groups[:, None] == groups[None, :]
    can_see_real = t_real_vis & real[None, :]
    if mode == "ensemble":
        vis = jnp.where(real[:, None], can_see_real,
                        can_see_real | (t_real_vis & same_group))
    elif mode == "decoder":
        vis = jnp.where(real[:, None], can_see_real, t_real_vis)
    elif mode == "encoder":
        vis = jnp.where(real[:, None], can_see_real,
                        t_real_vis | (same_group & ~real[:, None] & ~real[None, :]))
    else:
        raise ValueError(mode)
    return jnp.where(vis, 0.0, NEG_INF)
