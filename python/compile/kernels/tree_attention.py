"""L1 Pallas kernel: flash-style tree attention over a scattered KV cache.

Hardware adaptation (see DESIGN.md §5): the paper's hot spot is a GPU
tree-attention over a sparse mask.  On a TPU-shaped machine we express it
as a Pallas kernel gridded over (head, query-block); each grid step holds
one head's KV strip in VMEM and streams it in ``BLOCK_KV``-sized chunks
through a running-softmax (flash) accumulator, with the score matmul
shaped ``[bq, dh] x [dh, bk]`` so it feeds the MXU with contiguous tiles.
``BlockSpec`` expresses the HBM->VMEM schedule the CUDA implementations
express with thread blocks.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; correctness is validated against ``ref.py`` and
real-TPU performance is estimated structurally (VMEM footprint / MXU
utilization) in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9

# KV chunk streamed through the accumulator per iteration.  Swept in the
# perf pass (64/128/256); 128 keeps the per-step VMEM block at
# 128*dh*4B <= 20.5 KiB for the largest model while still giving the MXU
# a full 128-wide tile.
BLOCK_KV = 128


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, block_kv: int):
    """One (head, query-block) grid step.

    q_ref    [1, bq, dh]   VMEM block of queries for this head
    k_ref    [1, S,  dh]   this head's full key strip
    v_ref    [1, S,  dh]   this head's full value strip
    bias_ref [bq, S]       additive mask rows for this query block
    o_ref    [1, bq, dh]   output block
    """
    q = q_ref[0]  # [bq, dh]
    bq, dh = q.shape
    s_total = k_ref.shape[1]
    scale = (1.0 / (dh ** 0.5)).__float__()

    m0 = jnp.full((bq, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, dh), dtype=jnp.float32)

    def body(c, carry):
        m, l, acc = carry
        start = c * block_kv
        k = jax.lax.dynamic_slice(k_ref[0], (start, 0), (block_kv, dh))
        v = jax.lax.dynamic_slice(v_ref[0], (start, 0), (block_kv, dh))
        b = jax.lax.dynamic_slice(bias_ref[...], (0, start), (bq, block_kv))
        # [bq, bk] score tile — MXU-shaped matmul.
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + b
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, s_total // block_kv, body, (m0, l0, acc0))
    o_ref[0] = (acc / (l + 1e-9)).astype(o_ref.dtype)


def tree_attention(q, k, v, bias, *, block_q: int = 16, block_kv: int = BLOCK_KV,
                   interpret: bool = True):
    """Flash tree attention.  Same contract as ``ref.tree_attention_ref``.

    q [n, H, dh]; k, v [S, H, dh]; bias [n, S] -> out [n, H, dh].
    ``n`` must be a power of two (the AOT buckets are), S % block_kv == 0.
    """
    n, h, dh = q.shape
    s = k.shape[0]
    assert s % block_kv == 0, (s, block_kv)
    bq = min(n, block_q)
    assert n % bq == 0, (n, bq)

    # head-major layout so each grid step reads one contiguous strip
    qh = jnp.transpose(q, (1, 0, 2))  # [H, n, dh]
    kh = jnp.transpose(k, (1, 0, 2))  # [H, S, dh]
    vh = jnp.transpose(v, (1, 0, 2))

    grid = (h, n // bq)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_kv=block_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((bq, s), lambda ih, iq: (iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dh), q.dtype),
        interpret=interpret,
    )(qh, kh, vh, bias)
    return jnp.transpose(out, (1, 0, 2))  # [n, H, dh]


def vmem_report(n: int, s: int, h: int, dh: int,
                block_q: int = 16, block_kv: int = BLOCK_KV) -> dict:
    """Structural performance estimate for a real-TPU deployment.

    Returns the per-grid-step VMEM footprint in bytes and an MXU
    utilization proxy (fraction of the 128x128 systolic tile the score
    matmul fills).  Used by EXPERIMENTS.md §Perf; interpret-mode wallclock
    is *not* a TPU proxy.
    """
    bq = min(n, block_q)
    f32 = 4
    vmem = (
        bq * dh * f32            # q block
        + 2 * s * dh * f32       # k + v strips
        + bq * s * f32           # bias rows
        + bq * dh * f32          # out block
        + (2 * bq + bq * dh) * f32  # m, l, acc accumulators
    )
    mxu_fill = min(bq, 128) / 128 * min(dh, 128) / 128
    return {"vmem_bytes": vmem, "mxu_tile_fill": mxu_fill,
            "grid_steps": h * (n // bq), "block_kv": block_kv}
