"""Pure-jnp oracle for the tree-attention kernel.

This is the correctness reference the Pallas kernel (L1) is validated
against in ``python/tests/test_kernel.py``.  Shapes follow the inference
layout used by the whole stack:

  q     [n, H, dh]   queries for the n tree tokens of this decode step
  k, v  [S, H, dh]   the (already-scattered) KV cache, S = max_ctx
  bias  [n, S]       additive mask: 0 = visible, -1e9 = masked

The bias encodes *both* the committed-context visibility (slots below
``cache_len``) and the intra-tree ancestor structure (tree tokens were
scattered into their cache slots before attention runs).
"""

import jax.numpy as jnp

NEG_INF = -1e9


def tree_attention_ref(q, k, v, bias):
    """Masked multi-head attention of n query tokens over the full cache."""
    n, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(dh, dtype=q.dtype))
    # [H, n, S]
    scores = jnp.einsum("nhd,shd->hns", q, k) * scale + bias[None, :, :]
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-9)
    out = jnp.einsum("hns,shd->nhd", p, v)
    return out
