"""Minimal Adam + cosine-decay schedule (optax is not available offline).

Matches the paper's training recipe shape: cosine learning-rate schedule,
no warmup for prompt-token training (paper §5 Training), short linear
warmup for base-model training (standard practice; the base models are
*ours*, the paper freezes pretrained Vicunas).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adam_init(params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree_util.tree_map(jnp.zeros_like, params))


def adam_update(grads, state: AdamState, params, lr,
                b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                wd: float = 0.0):
    step = state.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m, v):
        return p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps) - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamState(step, mu, nu)


def cosine_lr(step, total_steps: int, base_lr: float, warmup: int = 0,
              final_frac: float = 0.05):
    """Cosine decay from base_lr to final_frac*base_lr with linear warmup."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0) if warmup else 1.0
    denom = jnp.maximum(jnp.asarray(total_steps, jnp.float32) - warmup, 1.0)
    prog = jnp.clip((step - warmup) / denom, 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * warm * cos
