"""Medusa-1 baseline: per-distance decoding heads on the frozen base LM.

Head k (k = 1..K) maps the hidden state at position t to a distribution
over the token at t+k+1 via a resblock + the frozen LM head:
``logits_k = lm_head(h + silu(h @ W_k))``.  Trained with the same KD
objective as PPD (teacher row t+k predicts t+k+1) so the comparison in
Table 1 / Fig 4 / Fig 6 isolates the *mechanism* (heads vs prompt
tokens), not the training recipe.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODELS, causal_bias, forward_train
from .corpus import build_corpus
from .data import StreamSampler
from .optim import adam_init, adam_update, cosine_lr

SEQ_LEN = 96
BATCH = 8
N_HEADS = 3
ALPHA = 0.8


def train_medusa(model: str, art: str, steps: int = 350, seed: int = 0,
                 log_every: int = 25) -> dict:
    cfg = MODELS[model]
    z = np.load(os.path.join(art, "train", f"{model}.npz"))
    base = {k: jnp.asarray(z[k]) for k in z.files}

    corpus = build_corpus(seed=0)
    sampler = StreamSampler(corpus.train_ids, SEQ_LEN, seed=seed + 3)
    bias = causal_bias(BATCH, SEQ_LEN)
    pos = jnp.broadcast_to(jnp.arange(SEQ_LEN, dtype=jnp.int32),
                           (BATCH, SEQ_LEN))

    wk = 0.02 * jax.random.normal(jax.random.PRNGKey(seed),
                                  (N_HEADS, cfg.d_model, cfg.d_model))
    opt = adam_init(wk)

    def loss_fn(wk, x):
        logits, hidden = forward_train(base, cfg, x, pos, bias,
                                       return_hidden=True)
        logits = jax.lax.stop_gradient(logits)
        hidden = jax.lax.stop_gradient(hidden)
        t = x.shape[1]
        total, count = 0.0, 0.0
        for k in range(1, N_HEADS + 1):
            hh = hidden + jax.nn.silu(jnp.einsum("btd,de->bte", hidden, wk[k - 1]))
            stu = jax.nn.log_softmax(hh @ base["lm_head"], axis=-1)
            # student at t predicts t+k+1 == teacher row t+k
            stu_v = stu[:, : t - k, :]
            tea = jax.nn.log_softmax(logits[:, k:, :], axis=-1)
            p_s = jnp.exp(stu_v)
            kl = jnp.sum(p_s * (stu_v - tea), axis=-1)
            total = total + (ALPHA ** (k - 1)) * jnp.sum(kl)
            count = count + kl.size
        return total / count

    @jax.jit
    def step_fn(wk, opt, x, step):
        loss, grads = jax.value_and_grad(loss_fn)(wk, x)
        lr = cosine_lr(step, steps, 2e-3, warmup=10)
        wk, opt = adam_update(grads, opt, wk, lr)
        return wk, opt, loss

    log = {"model": model, "loss": []}
    t0 = time.time()
    for i, (x, _) in enumerate(sampler.windows(BATCH, steps)):
        wk, opt, loss = step_fn(wk, opt, jnp.asarray(x), jnp.asarray(i))
        if i % log_every == 0:
            log["loss"].append([i, float(loss)])
            print(f"[medusa {model}] step {i:4d} loss {float(loss):.4f}")
    log["wall_s"] = time.time() - t0
    print(f"[medusa {model}] done in {log['wall_s']:.1f}s")

    np.savez(os.path.join(art, "train", f"{model}-medusa.npz"),
             wk=np.asarray(wk), lm_head=np.asarray(base["lm_head"]))
    os.makedirs(os.path.join(art, "train_logs"), exist_ok=True)
    with open(os.path.join(art, "train_logs", f"medusa_{model}.json"), "w") as f:
        json.dump(log, f)
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="ppd-s,ppd-m,ppd-l")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=350)
    args = ap.parse_args()
    for m in args.models.split(","):
        train_medusa(m, args.out, steps=args.steps)


if __name__ == "__main__":
    main()
