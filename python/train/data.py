"""Batching utilities over the synthetic token streams."""

from __future__ import annotations

import numpy as np


class StreamSampler:
    """Uniform random windows over a flat token stream."""

    def __init__(self, ids: list[int] | np.ndarray, seq_len: int, seed: int = 0):
        self.ids = np.asarray(ids, dtype=np.int32)
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        assert len(self.ids) > seq_len + 1, "stream too short"

    def batch(self, batch_size: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (x [B,T], y [B,T]) with y the next-token targets."""
        t = self.seq_len
        starts = self.rng.integers(0, len(self.ids) - t - 1, size=batch_size)
        x = np.stack([self.ids[s:s + t] for s in starts])
        y = np.stack([self.ids[s + 1:s + t + 1] for s in starts])
        return x, y

    def windows(self, batch_size: int, count: int):
        for _ in range(count):
            yield self.batch(batch_size)
