"""Estimate acceptance statistics on the validation set (build-time).

Produces ``artifacts/<model>/accept_stats*.json`` with, for PPD prompt
tokens and for the Medusa-head baseline:

  exact[d][r]  P(the rank-(r+1) candidate at token distance d+1 is the
               true token)  — drives dynamic-sparse-tree construction
               (Prop 4.1's path probabilities) in rust
  cum[d][r]    accumulative top-(r+1) accuracy — the Fig 6 series

Token-distance convention (paper Fig 6): distance d predicts the token
d+1 positions after the conditioning context's last token, i.e. prompt
token k (0-based) and Medusa head k+1 both operate at distance k+1.

The same estimator also records next-token (distance-0, LM head) rank
accuracies used to seed depth-1 of the *vanilla* speculative chain and
the τ estimates in rust.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODELS
from compile.model import forward_train, causal_bias
from .corpus import build_corpus
from .data import StreamSampler
from .train_prompt import T_REAL, TrainCfg, build_prompt_batch

TOP_R = 10


def _rank_counts(logits: np.ndarray, truth: np.ndarray, valid: np.ndarray,
                 acc: np.ndarray, tot: np.ndarray, d_idx: np.ndarray):
    """Accumulate exact-rank hits.  logits [N,V], truth [N], valid [N],
    d_idx [N] distance row index into acc/tot."""
    r = min(TOP_R, logits.shape[-1])
    order = np.argsort(-logits, axis=-1)[:, :r]  # [N, r]
    hit = np.zeros((logits.shape[0], TOP_R), bool)
    hit[:, :r] = order == truth[:, None]
    for d in range(acc.shape[0]):
        m = (d_idx == d) & (valid > 0)
        if m.any():
            acc[d] += hit[m].sum(axis=0)
            tot[d] += m.sum()


def eval_model(model: str, art: str, variant: str | None = None,
               n_windows: int = 96, batch: int = 8, n_ept: int = 1,
               agg: str = "mean", seed: int = 0) -> dict:
    cfg = MODELS[model]
    z = np.load(os.path.join(art, "train", f"{model}.npz"))
    params = {k: jnp.asarray(z[k]) for k in z.files}
    agg_w = None
    if variant:
        vz = np.load(os.path.join(art, "train", "variants",
                                  f"{model}_{variant}.npz"))
        params = dict(params)
        params["prompt_emb"] = jnp.asarray(vz["prompt_emb"])
        if "agg_w" in vz.files:
            agg_w = jax.nn.softmax(jnp.asarray(vz["agg_w"]))

    corpus = build_corpus(seed=0)
    sampler = StreamSampler(corpus.val_ids, T_REAL, seed=seed + 11)
    rng = np.random.default_rng(seed + 17)
    tc = TrainCfg(model=model, n_ept=n_ept, inserts=6)
    k_n = cfg.n_prompt

    fwd = jax.jit(lambda p, t, ps, b: forward_train(p, cfg, t, ps, b))

    ppd_acc = np.zeros((k_n, TOP_R))
    ppd_tot = np.zeros(k_n)
    lm_acc = np.zeros((1, TOP_R))
    lm_tot = np.zeros(1)

    # Medusa heads, if trained
    med_path = os.path.join(art, "train", f"{model}-medusa.npz")
    medusa = np.load(med_path) if os.path.exists(med_path) else None
    med_acc = np.zeros((k_n, TOP_R))
    med_tot = np.zeros(k_n)

    steps = max(1, n_windows // batch)
    for _ in range(steps):
        x, y = sampler.batch(batch)
        nb = build_prompt_batch(x, tc, k_n, rng)
        logits = np.asarray(fwd(params, jnp.asarray(nb["tokens"]),
                                jnp.asarray(nb["pos"]),
                                jnp.asarray(nb["bias"])))
        b = x.shape[0]
        # PPD: student logits at prompt rows
        sidx = nb["sidx"]  # [B,I,K,E]
        stu = np.take_along_axis(
            logits, sidx.reshape(b, -1)[..., None], axis=1
        ).reshape(*sidx.shape, logits.shape[-1])
        if agg_w is not None:
            stu = np.einsum("bikev,e->bikv", stu, np.asarray(agg_w))
        else:
            stu = stu.mean(axis=3)  # [B,I,K,V]
        flat = stu.reshape(-1, stu.shape[-1])
        truth = nb["hard"].reshape(-1)
        valid = nb["valid"].reshape(-1)
        d_idx = np.tile(np.arange(k_n), b * tc.inserts)
        _rank_counts(flat, truth, valid, ppd_acc, ppd_tot, d_idx)

        # LM head next-token (distance 0): real rows predict the shift
        n_prefix = k_n if tc.prefix else 0
        real = logits[:, n_prefix:n_prefix + T_REAL - 1, :].reshape(-1, logits.shape[-1])
        truth0 = x[:, 1:].reshape(-1)
        _rank_counts(real, truth0, np.ones_like(truth0, np.float32),
                     lm_acc, lm_tot, np.zeros_like(truth0))

        if medusa is not None:
            # hidden = logits pre-head unavailable here; recompute forward
            # with hidden via the plain causal path (cheap at this size)
            cb = causal_bias(b, T_REAL)
            pos = jnp.broadcast_to(jnp.arange(T_REAL, dtype=jnp.int32),
                                   (b, T_REAL))
            _, hidden = forward_train(params, cfg, jnp.asarray(x), pos, cb,
                                      return_hidden=True)
            hidden = np.asarray(hidden)
            for k in range(1, k_n + 1):
                hh = hidden + np.asarray(
                    jax.nn.silu(jnp.asarray(hidden) @ jnp.asarray(medusa["wk"][k - 1])))
                ml = hh @ medusa["lm_head"]
                stu_v = ml[:, : T_REAL - k - 1, :].reshape(-1, ml.shape[-1])
                truth_k = x[:, k + 1:].reshape(b, -1)[:, : T_REAL - k - 1].reshape(-1)
                _rank_counts(stu_v, truth_k,
                             np.ones_like(truth_k, np.float32),
                             med_acc[k - 1:k], med_tot[k - 1:k],
                             np.zeros_like(truth_k))

    def pack(acc, tot):
        exact = acc / np.maximum(tot[:, None], 1)
        return {"exact": exact.tolist(), "cum": np.cumsum(exact, -1).tolist(),
                "n": tot.tolist()}

    stats = {
        "model": model, "variant": variant or "default",
        "lm": pack(lm_acc, lm_tot),
        "ppd": pack(ppd_acc, ppd_tot),
    }
    if medusa is not None:
        stats["medusa"] = pack(med_acc, med_tot)

    out_dir = os.path.join(art, model)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{variant}" if variant else ""
    path = os.path.join(out_dir, f"accept_stats{suffix}.json")
    with open(path, "w") as f:
        json.dump(stats, f, indent=1)
    print(f"[eval {model}{suffix}] ppd top-1 by distance:",
          [round(r[0], 3) for r in stats["ppd"]["exact"]])
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="ppd-s,ppd-m,ppd-l,ppd-d")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variant", default="")
    ap.add_argument("--ept", type=int, default=1)
    ap.add_argument("--agg", default="mean")
    args = ap.parse_args()
    for m in args.models.split(","):
        eval_model(m, args.out, variant=args.variant or None,
                   n_ept=args.ept, agg=args.agg)


if __name__ == "__main__":
    main()
