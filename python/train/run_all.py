"""Build-time orchestrator: corpus -> base LMs -> prompt tokens -> Medusa
heads -> acceptance stats -> serving traces.  Idempotent: finished stages
are skipped when their outputs exist (delete ``artifacts/train`` to
retrain).  ``--fast`` trains a tiny configuration for CI/smoke runs.

Ablation variants (appendix tables) are behind ``--ablations`` because
they multiply training time; `make ablations` runs them.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from . import corpus as corpus_mod
from .eval_accept import eval_model
from .train_base import train_model
from .train_medusa import train_medusa
from .train_prompt import TrainCfg, train_prompt

MODELS = ["ppd-s", "ppd-m", "ppd-l", "ppd-d"]
MEDUSA_MODELS = ["ppd-s", "ppd-m", "ppd-l"]


def _exists(art, rel):
    return os.path.exists(os.path.join(art, rel))


def stage_corpus(art: str):
    c = corpus_mod.build_corpus(seed=0)
    corpus_mod.write_artifacts(c, art)
    print("[run_all] corpus + traces written")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--ablations", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    art = args.out
    os.makedirs(art, exist_ok=True)
    t0 = time.time()
    timings = {}

    stage_corpus(art)

    models = ["ppd-d", "ppd-s"] if args.fast else MODELS
    base_steps = 120 if args.fast else 0
    prompt_steps = 80 if args.fast else 350
    for m in models:
        if args.force or not _exists(art, f"train/{m}.npz"):
            s = time.time()
            train_model(m, art, steps=base_steps or None)
            timings[f"base_{m}"] = time.time() - s
    for m in models:
        if args.force or not _exists(art, f"train_logs/prompt_{m}_ept1.json"):
            s = time.time()
            train_prompt(TrainCfg(model=m, steps=prompt_steps), art)
            timings[f"prompt_{m}"] = time.time() - s
    med = ["ppd-s"] if args.fast else MEDUSA_MODELS
    for m in med:
        if args.force or not _exists(art, f"train/{m}-medusa.npz"):
            s = time.time()
            train_medusa(m, art, steps=prompt_steps)
            timings[f"medusa_{m}"] = time.time() - s

    for m in models:
        if args.force or not _exists(art, f"{m}/accept_stats.json"):
            eval_model(m, art)

    if args.ablations:
        run_ablations(art, prompt_steps)

    timings["total"] = time.time() - t0
    with open(os.path.join(art, "train_logs", "timings.json"), "w") as f:
        json.dump(timings, f, indent=1)
    print(f"[run_all] done in {timings['total']:.0f}s")


def run_ablations(art: str, steps: int, model: str = "ppd-s"):
    """Appendix-B variants, all on the small model for tractable CPU time.
    Paper's EPT=100 maps to EPT=16 here (same trend axis, scaled to the
    tiny embedding dim — see DESIGN.md §2)."""
    variants = [
        TrainCfg(model=model, steps=steps, n_ept=4),                  # Table 2
        TrainCfg(model=model, steps=steps, n_ept=16, inserts=4),      # Table 2
        TrainCfg(model=model, steps=steps, kd=False),                 # Table 3
        TrainCfg(model=model, steps=steps, n_ept=4, kd=False),        # Table 3
        TrainCfg(model=model, steps=steps, prefix=True),              # Table 4
        TrainCfg(model=model, steps=steps, custom_head="1-stage"),    # Table 5
        TrainCfg(model=model, steps=steps, custom_head="2-stage"),    # Table 5
        TrainCfg(model=model, steps=steps, n_ept=4, mask_mode="decoder"),   # T6
        TrainCfg(model=model, steps=steps, n_ept=4, mask_mode="encoder"),   # T6
        TrainCfg(model=model, steps=steps, n_ept=4, agg="learned"),   # Table 7
        TrainCfg(model=model, steps=steps, multi_exit=2),             # Table 8
        TrainCfg(model=model, steps=steps, multi_exit=3),             # Table 8
    ]
    for tc in variants:
        name = tc.variant_name()
        if not os.path.exists(os.path.join(
                art, "train_logs", f"prompt_{model}_{name}.json")):
            train_prompt(tc, art)
        eval_model(model, art, variant=name, n_ept=tc.n_ept,
                   agg=tc.agg)


if __name__ == "__main__":
    main()
