"""Synthetic corpus generator for the PPD reproduction.

The paper trains prompt-token embeddings on ShareGPT and evaluates on
MT-Bench / GSM8K / HumanEval.  None of those are available here (and the
base models — Vicuna — aren't either), so we synthesize a byte-level
mini-language with the property PPD exploits: *predictable local structure*
(common phrases, repeated symbols, formulaic patterns).  Three task
families mirror the paper's benchmark split:

  * ``chat`` — templated instruction/answer dialogues (MT-Bench analogue)
  * ``math`` — formatted arithmetic with real results (GSM8K analogue)
  * ``code`` — tiny python-like function snippets (HumanEval analogue)

``code`` and ``math`` are intentionally more formulaic than ``chat`` so the
relative speedup ordering of Fig. 5 (code/math > chat) is reproducible.

All text is ASCII < 128 and the tokenizer is identity-over-bytes
(vocab = 128).  Special ids: PAD=0, BOS=1 (ASCII SOH), EOS=2 (ASCII STX) —
all below 32 and never produced by the generator's printable text.
"""

from __future__ import annotations

import json
import os
import random
from dataclasses import dataclass, field

VOCAB_SIZE = 128
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

# ---------------------------------------------------------------------------
# tokenizer: identity over ASCII bytes
# ---------------------------------------------------------------------------


def encode(text: str) -> list[int]:
    """Byte-level encode; non-ASCII characters are dropped."""
    return [b for b in text.encode("ascii", errors="ignore")]


def decode(ids: list[int]) -> str:
    return bytes(i for i in ids if 32 <= i < 128 or i in (9, 10)).decode("ascii")


# ---------------------------------------------------------------------------
# chat task
# ---------------------------------------------------------------------------

_SUBJECTS = [
    "the sky", "a river", "the moon", "a forest", "the ocean", "a mountain",
    "the sun", "a garden", "the wind", "a city", "the desert", "a lake",
]
_ADJECTIVES = [
    "blue", "calm", "bright", "green", "vast", "tall", "warm", "quiet",
    "dry", "deep", "cold", "wide",
]
_TOPICS = [
    "color", "place", "season", "animal", "food", "book", "song", "sport",
]
_ANSWER_PHRASES = [
    "my favorite {t} is {a} because it reminds me of {s}.",
    "i would say {a}, since {s} is {a} most of the time.",
    "that would be {a}. i think of {s} when i hear it.",
]
_QUESTION_PHRASES = [
    "what is your favorite {t}?",
    "tell me about your favorite {t}.",
    "which {t} do you like the most?",
]


def _zipf_choice(rng: random.Random, items: list[str]) -> str:
    """Zipf-ish pick: low indices are much more likely (common phrases)."""
    n = len(items)
    weights = [1.0 / (i + 1) for i in range(n)]
    return rng.choices(items, weights=weights, k=1)[0]


def gen_chat(rng: random.Random) -> str:
    t = _zipf_choice(rng, _TOPICS)
    a = _zipf_choice(rng, _ADJECTIVES)
    s = _zipf_choice(rng, _SUBJECTS)
    q = _zipf_choice(rng, _QUESTION_PHRASES).format(t=t)
    ans = _zipf_choice(rng, _ANSWER_PHRASES).format(t=t, a=a, s=s)
    return f"user: {q}\nassistant: {ans}\n"


# ---------------------------------------------------------------------------
# math task
# ---------------------------------------------------------------------------


def gen_math(rng: random.Random) -> str:
    lines = []
    for _ in range(rng.randint(2, 4)):
        a = rng.randint(2, 99)
        b = rng.randint(2, 99)
        op = rng.choice(["+", "-", "*"])
        r = {"+": a + b, "-": a - b, "*": a * b}[op]
        lines.append(f"calc: {a} {op} {b} = {r} ;")
    return " ".join(lines) + "\n"


# ---------------------------------------------------------------------------
# code task
# ---------------------------------------------------------------------------

_FN_OPS = [("add", "+"), ("sub", "-"), ("mul", "*")]
_VARS = ["a", "b", "c", "x", "y", "n", "m"]


def gen_code(rng: random.Random) -> str:
    name, op = rng.choice(_FN_OPS)
    v1, v2 = rng.sample(_VARS, 2)
    body = [
        f"def {name}_{v1}_{v2}({v1}, {v2}):",
        f"    result = {v1} {op} {v2}",
        "    return result",
        "",
    ]
    if rng.random() < 0.5:
        k = rng.randint(1, 9)
        body.insert(2, f"    for i in range({k}):")
        body.insert(3, f"        {v1} = {v1} {op} i")
    return "\n".join(body) + "\n"


_TASKS = {"chat": gen_chat, "math": gen_math, "code": gen_code}


# ---------------------------------------------------------------------------
# corpus assembly
# ---------------------------------------------------------------------------


@dataclass
class Corpus:
    """Token-level corpus with per-task splits."""

    train_ids: list[int] = field(default_factory=list)
    val_ids: list[int] = field(default_factory=list)
    # task -> list of prompt/reference pairs (token ids) for serving traces
    traces: dict = field(default_factory=dict)


def build_corpus(
    seed: int = 0,
    train_bytes: int = 600_000,
    val_bytes: int = 60_000,
    trace_prompts: int = 32,
) -> Corpus:
    """Generate the mixed training stream, validation stream, and per-task
    serving traces (prompt + reference continuation)."""
    rng = random.Random(seed)
    c = Corpus()

    def stream(n_bytes: int, r: random.Random) -> list[int]:
        out: list[int] = []
        while len(out) < n_bytes:
            task = r.choice(list(_TASKS))
            out.extend(encode(_TASKS[task](r)))
        return out[:n_bytes]

    c.train_ids = stream(train_bytes, rng)
    c.val_ids = stream(val_bytes, random.Random(seed + 1))

    trace_rng = random.Random(seed + 2)
    for task, gen in _TASKS.items():
        pairs = []
        for _ in range(trace_prompts):
            # Several documents; the last one is split into (prompt, ref).
            ctx = "".join(gen(trace_rng) for _ in range(2))
            doc = gen(trace_rng)
            cut = max(8, len(doc) // 3)
            prompt = encode(ctx + doc[:cut])
            ref = encode(doc[cut:])
            pairs.append({"prompt": prompt, "reference": ref})
        c.traces[task] = pairs
    return c


def write_artifacts(corpus: Corpus, out_dir: str) -> None:
    os.makedirs(os.path.join(out_dir, "traces"), exist_ok=True)
    for task, pairs in corpus.traces.items():
        with open(os.path.join(out_dir, "traces", f"{task}.json"), "w") as f:
            json.dump(pairs, f)
    with open(os.path.join(out_dir, "traces", "val_ids.json"), "w") as f:
        json.dump(corpus.val_ids[:16384], f)


if __name__ == "__main__":
    c = build_corpus()
    print("train bytes:", len(c.train_ids))
    print(decode(c.train_ids[:200]))
