"""Train the base byte-level LMs (the frozen "original LLMs" of the paper).

The paper freezes pretrained Vicuna checkpoints; we have none, so we
pretrain tiny analogues on the synthetic corpus (DESIGN.md §2).  Standard
next-token cross-entropy, Adam + cosine LR.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODELS, causal_bias, forward_train, init_params
from .corpus import build_corpus
from .data import StreamSampler
from .optim import adam_init, adam_update, cosine_lr

# steps tuned so each model trains in a few minutes on one CPU core while
# reaching low perplexity on the (deliberately predictable) corpus
DEFAULT_STEPS = {"ppd-s": 600, "ppd-m": 700, "ppd-l": 700, "ppd-d": 500}
SEQ_LEN = 96
BATCH = 8
BASE_LR = 3e-3


def ce_loss(params, cfg, x, y, bias, pos):
    logits = forward_train(params, cfg, x, pos, bias)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_model(model: str, art: str, steps: int | None = None,
                seed: int = 0, log_every: int = 25) -> dict:
    cfg = MODELS[model]
    steps = steps or DEFAULT_STEPS[model]
    corpus = build_corpus(seed=0)
    sampler = StreamSampler(corpus.train_ids, SEQ_LEN, seed=seed)
    val = StreamSampler(corpus.val_ids, SEQ_LEN, seed=seed + 1)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adam_init(params)
    bias = causal_bias(BATCH, SEQ_LEN)
    pos = jnp.broadcast_to(jnp.arange(SEQ_LEN, dtype=jnp.int32),
                           (BATCH, SEQ_LEN))

    @jax.jit
    def step_fn(params, opt, x, y, step):
        loss, grads = jax.value_and_grad(ce_loss)(params, cfg, x, y, bias, pos)
        lr = cosine_lr(step, steps, BASE_LR, warmup=20)
        params, opt = adam_update(grads, opt, params, lr)
        return params, opt, loss

    log = {"model": model, "steps": steps, "loss": [], "wall_s": 0.0}
    t0 = time.time()
    for i, (x, y) in enumerate(sampler.windows(BATCH, steps)):
        params, opt, loss = step_fn(params, opt, jnp.asarray(x),
                                    jnp.asarray(y), jnp.asarray(i))
        if i % log_every == 0 or i == steps - 1:
            log["loss"].append([i, float(loss)])
            print(f"[base {model}] step {i:4d} loss {float(loss):.4f}")
    log["wall_s"] = time.time() - t0

    # held-out perplexity
    vx, vy = val.batch(BATCH)
    vl = ce_loss(params, cfg, jnp.asarray(vx), jnp.asarray(vy), bias, pos)
    log["val_loss"] = float(vl)
    print(f"[base {model}] done in {log['wall_s']:.1f}s val_loss={float(vl):.4f}")

    os.makedirs(os.path.join(art, "train"), exist_ok=True)
    np.savez(os.path.join(art, "train", f"{model}.npz"),
             **{k: np.asarray(v) for k, v in params.items()})
    os.makedirs(os.path.join(art, "train_logs"), exist_ok=True)
    with open(os.path.join(art, "train_logs", f"base_{model}.json"), "w") as f:
        json.dump(log, f)
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="ppd-s,ppd-m,ppd-l,ppd-d")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    for m in args.models.split(","):
        train_model(m, args.out, steps=args.steps or None)


if __name__ == "__main__":
    main()
