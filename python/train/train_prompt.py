"""PPD prompt-token training (paper §3.3) plus every appendix-B ablation.

Only the prompt-token embeddings are trainable; the base LM stays frozen.
Two paper techniques:

* **Random insertion** — prompt-token blocks are "inserted" at random
  points of each training window.  Implementation detail: the blocks are
  physically appended after the real tokens but get the *position ids and
  attention visibility* of their insertion point, which is equivalent
  under RoPE + masked attention and keeps the real-token rows contiguous.
* **Knowledge distillation** (Eq. 1) — the KD target for the prompt token
  at insertion i / distance k is the base model's distribution at real
  position i+k (which predicts token i+k+1).  Because real tokens never
  attend to prompt tokens, ONE forward pass yields both the (unperturbed)
  teacher rows and the student rows.

Variants (appendix B), selected by TrainCfg flags:
  n_ept            Table 2/3 — ensemble prompt tokens per prompt token
  kd=False         Table 3  — hard-label CE instead of KD
  mask_mode        Table 6  — ensemble / decoder / encoder EPT masking
  agg              Table 7  — mean vs learned-weight logit aggregation
  prefix           Table 4  — per-distance prefix tokens visible only to
                              prompt tokens (sequence-level approximation
                              of prefix tuning; see DESIGN.md §2)
  custom_head      Table 5  — shared Medusa-style resblock head on prompt
                              hidden states (1-stage or 2-stage)
  multi_exit       Table 8  — average the last-k layer activations of
                              prompt positions before the LM head
"""

from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODELS, NEG_INF, VOCAB, forward_train, rmsnorm
from .corpus import build_corpus
from .data import StreamSampler
from .optim import adam_init, adam_update, cosine_lr

T_REAL = 96


@dataclass(frozen=True)
class TrainCfg:
    model: str = "ppd-m"
    steps: int = 350
    batch: int = 8
    inserts: int = 6          # insertion points per window
    n_ept: int = 1
    kd: bool = True
    alpha: float = 0.8        # Eq. 1 decay ratio
    lr: float = 1e-2          # paper: cosine from 0.01, no warmup
    mask_mode: str = "ensemble"
    agg: str = "mean"         # or "learned"
    prefix: bool = False
    custom_head: str = "none"  # none | 1-stage | 2-stage
    multi_exit: int = 0        # 0 = off, else #exits
    seed: int = 0

    def variant_name(self) -> str:
        bits = [f"ept{self.n_ept}"]
        if not self.kd:
            bits.append("nokd")
        if self.mask_mode != "ensemble":
            bits.append(self.mask_mode)
        if self.agg != "mean":
            bits.append(self.agg)
        if self.prefix:
            bits.append("prefix")
        if self.custom_head != "none":
            bits.append(f"head{self.custom_head}")
        if self.multi_exit:
            bits.append(f"exit{self.multi_exit}")
        return "-".join(bits)


# ---------------------------------------------------------------------------
# batch construction (host-side numpy; see module docstring)
# ---------------------------------------------------------------------------


def build_prompt_batch(x: np.ndarray, tc: TrainCfg, n_prompt: int,
                       rng: np.random.Generator):
    """Expand real windows [B, T_REAL] with inserted prompt blocks.

    Returns dict of numpy arrays:
      tokens  [B, T]      real tokens then prompt blocks (+ prefix rows)
      pos     [B, T]      RoPE position ids
      bias    [B, T, T]   additive attention bias
      tgt     [B, I, K]   teacher row index for each (insert, distance)
      sidx    [B, I, K, E] student row indices (per EPT)
      hard    [B, I, K]   hard labels (token at insertion+distance+1)
      valid   [B, I, K]   1 where the target is inside the window
    """
    b, tr = x.shape
    assert tr == T_REAL
    i_n, k_n, e_n = tc.inserts, n_prompt, tc.n_ept
    n_prefix = k_n if tc.prefix else 0
    t = n_prefix + tr + i_n * k_n * e_n

    tokens = np.zeros((b, t), np.int32)
    pos = np.zeros((b, t), np.int32)
    kinds = np.zeros((b, t), np.int32)      # 0 real, 1 prompt, 2 prefix
    bias = np.full((b, t, t), NEG_INF, np.float32)
    tgt = np.zeros((b, i_n, k_n), np.int32)
    sidx = np.zeros((b, i_n, k_n, e_n), np.int32)
    hard = np.zeros((b, i_n, k_n), np.int32)
    valid = np.zeros((b, i_n, k_n), np.float32)

    p0 = n_prefix  # real tokens start here
    for bi in range(b):
        # prefix rows (ids VOCAB + n_prompt*n_ept + j in the extended table)
        for j in range(n_prefix):
            tokens[bi, j] = VOCAB + k_n * e_n + j
            pos[bi, j] = 0
            kinds[bi, j] = 2
            bias[bi, j, j] = 0.0
        tokens[bi, p0:p0 + tr] = x[bi]
        pos[bi, p0:p0 + tr] = np.arange(tr)
        # real-real causal
        rr = np.tril(np.ones((tr, tr), np.float32))
        bias[bi, p0:p0 + tr, p0:p0 + tr] = np.where(rr > 0, 0.0, NEG_INF)

        inserts = rng.choice(np.arange(4, tr - k_n - 2), size=i_n,
                             replace=False)
        w = p0 + tr  # write head for prompt rows
        for ii, ins in enumerate(sorted(inserts)):
            for k in range(k_n):       # distance k+1
                for e in range(e_n):
                    a = w
                    w += 1
                    tokens[bi, a] = VOCAB + k * e_n + e
                    pos[bi, a] = ins + k + 1
                    kinds[bi, a] = 1
                    sidx[bi, ii, k, e] = a
                    # sees real prefix (causal up to insertion point)
                    bias[bi, a, p0:p0 + ins + 1] = 0.0
                    bias[bi, a, a] = 0.0
                    # sees earlier prompt tokens at the same insertion
                    for k2 in range(k):
                        for e2 in range(e_n):
                            a2 = sidx[bi, ii, k2, e2]
                            see = (
                                e2 == e if tc.mask_mode == "ensemble"
                                else True  # decoder/encoder: all earlier
                            )
                            if see:
                                bias[bi, a, a2] = 0.0
                    if tc.mask_mode == "encoder":
                        # EPTs of the same prompt token see each other
                        for e2 in range(e_n):
                            a2 = sidx[bi, ii, k, e2]
                            if a2:
                                bias[bi, a, a2] = 0.0
                                bias[bi, a2, a] = 0.0
                    if tc.prefix:
                        bias[bi, a, k] = 0.0  # its own prefix row only
                tgt_pos = ins + k + 1      # teacher row predicts ins+k+2
                if tgt_pos < tr - 1:
                    tgt[bi, ii, k] = p0 + tgt_pos
                    hard[bi, ii, k] = x[bi, tgt_pos + 1]
                    valid[bi, ii, k] = 1.0
    return dict(tokens=tokens, pos=pos, bias=bias, tgt=tgt, sidx=sidx,
                hard=hard, valid=valid)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def make_loss_fn(cfg, tc: TrainCfg):
    k_n = cfg.n_prompt

    def loss_fn(trainable, frozen, batch):
        params = {**frozen, **trainable,
                  "prompt_emb": trainable["prompt_emb"]}
        if tc.multi_exit:
            logits, _, layers = forward_train(
                params, cfg, batch["tokens"], batch["pos"], batch["bias"],
                collect_layers=True)
            ex = jnp.mean(jnp.stack(layers[-tc.multi_exit:]), axis=0)
            ex_logits = rmsnorm(ex, params["final_norm"]) @ params["lm_head"]
        else:
            if tc.custom_head != "none":
                base_logits, hidden = forward_train(
                    params, cfg, batch["tokens"], batch["pos"], batch["bias"],
                    return_hidden=True)
                hh = hidden + jax.nn.silu(hidden @ trainable["head_w"])
                head_logits = hh @ params["lm_head"]
                logits = base_logits
            else:
                logits = forward_train(params, cfg, batch["tokens"],
                                       batch["pos"], batch["bias"])

        def gather_rows(src, idx):
            # src [B,T,V], idx [B,...] -> [B,...,V]
            return jnp.take_along_axis(
                src, idx.reshape(idx.shape[0], -1)[..., None], axis=1
            ).reshape(*idx.shape, src.shape[-1])

        teacher = jax.lax.stop_gradient(gather_rows(logits, batch["tgt"]))
        if tc.multi_exit:
            student_src = ex_logits
        elif tc.custom_head != "none":
            student_src = head_logits
        else:
            student_src = logits
        stu = gather_rows(student_src, batch["sidx"])  # [B,I,K,E,V]
        if tc.agg == "learned":
            w = jax.nn.softmax(trainable["agg_w"])
            stu = jnp.einsum("bikev,e->bikv", stu, w)
        else:
            stu = jnp.mean(stu, axis=3)

        logp_s = jax.nn.log_softmax(stu, axis=-1)
        decay = tc.alpha ** jnp.arange(k_n, dtype=jnp.float32)  # [K]
        if tc.kd:
            logp_t = jax.nn.log_softmax(teacher, axis=-1)
            p_s = jnp.exp(logp_s)
            kl = jnp.sum(p_s * (logp_s - logp_t), axis=-1)  # [B,I,K]
            per = kl
        else:
            nll = -jnp.take_along_axis(logp_s, batch["hard"][..., None],
                                       axis=-1)[..., 0]
            per = nll
        per = per * batch["valid"] * decay[None, None, :]
        return jnp.sum(per) / (jnp.sum(batch["valid"]) + 1e-9)

    return loss_fn


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def init_trainable(cfg, tc: TrainCfg, base_params, key) -> dict:
    """Prompt embeddings initialized from normal text-token embeddings
    (paper §5 Training) + variant-specific extras."""
    rows = cfg.n_prompt * tc.n_ept
    key, k1, k2 = jax.random.split(key, 3)
    idx = jax.random.randint(k1, (rows,), 32, VOCAB)
    prompt_emb = base_params["tok_emb"][idx] + \
        0.01 * jax.random.normal(k2, (rows, cfg.d_model))
    tr = {"prompt_emb": prompt_emb}
    if tc.prefix:
        key, k3 = jax.random.split(key)
        pidx = jax.random.randint(k3, (cfg.n_prompt,), 32, VOCAB)
        tr["prefix_emb"] = base_params["tok_emb"][pidx]
    if tc.agg == "learned":
        tr["agg_w"] = jnp.zeros((tc.n_ept,))
    if tc.custom_head != "none":
        key, k4 = jax.random.split(key)
        tr["head_w"] = 0.02 * jax.random.normal(
            k4, (cfg.d_model, cfg.d_model))
    return tr


def train_prompt(tc: TrainCfg, art: str, log_every: int = 25) -> dict:
    cfg0 = MODELS[tc.model]
    # the L2 config's n_ept describes inference artifacts (always 1);
    # training may use more EPT rows
    cfg = replace(cfg0, n_ept=tc.n_ept) if hasattr(cfg0, "n_ept") else cfg0

    z = np.load(os.path.join(art, "train", f"{tc.model}.npz"))
    base = {k: jnp.asarray(z[k]) for k in z.files}

    corpus = build_corpus(seed=0)
    sampler = StreamSampler(corpus.train_ids, T_REAL, seed=tc.seed + 7)
    rng = np.random.default_rng(tc.seed + 13)

    trainable = init_trainable(cfg, tc, base, jax.random.PRNGKey(tc.seed))
    frozen = {k: v for k, v in base.items() if k != "prompt_emb"}
    if tc.prefix:
        # prefix rows live in the extended embedding table after EPT rows
        frozen = dict(frozen)

    loss_fn = make_loss_fn(cfg, tc)

    def merge_prompt(tr):
        if tc.prefix:
            tr = dict(tr)
            tr["prompt_emb"] = jnp.concatenate(
                [tr["prompt_emb"], tr.pop("prefix_emb")], axis=0)
        return tr

    def loss_merged(tr, frozen, batch):
        return loss_fn(merge_prompt(tr), frozen, batch)

    opt = adam_init(trainable)

    stages = [(tc.steps, tc.lr)]
    if tc.custom_head == "2-stage":
        stages = [(tc.steps // 2, tc.lr), (tc.steps - tc.steps // 2, tc.lr / 5)]

    total_steps = sum(s for s, _ in stages)

    def make_step(lr0):
        @jax.jit
        def step_fn(trainable, opt, batch, step):
            loss, grads = jax.value_and_grad(loss_merged)(
                trainable, frozen, batch)
            lr = cosine_lr(step, total_steps, lr0, warmup=0)
            trainable, opt = adam_update(grads, opt, trainable, lr)
            return trainable, opt, loss
        return step_fn

    log = {"model": tc.model, "variant": tc.variant_name(), "loss": []}
    t0 = time.time()
    gstep = 0
    for total, lr0 in stages:
        step_fn = make_step(lr0)
        for _ in range(total):
            x, _ = sampler.batch(tc.batch)
            nb = build_prompt_batch(x, tc, cfg.n_prompt, rng)
            batch = {k: jnp.asarray(v) for k, v in nb.items()}
            trainable, opt, loss = step_fn(trainable, opt, batch,
                                           jnp.asarray(gstep))
            if gstep % log_every == 0:
                log["loss"].append([gstep, float(loss)])
                print(f"[prompt {tc.model}/{tc.variant_name()}] "
                      f"step {gstep:4d} loss {float(loss):.4f}")
            gstep += 1
    log["wall_s"] = time.time() - t0
    print(f"[prompt {tc.model}/{tc.variant_name()}] done {log['wall_s']:.1f}s")

    # save: default variant merges prompt_emb into the model params
    merged = merge_prompt(dict(trainable))
    os.makedirs(os.path.join(art, "train", "variants"), exist_ok=True)
    vpath = os.path.join(art, "train", "variants",
                         f"{tc.model}_{tc.variant_name()}.npz")
    np.savez(vpath, **{k: np.asarray(v) for k, v in merged.items()})
    if tc.variant_name() == "ept1":
        out = dict(base)
        out["prompt_emb"] = merged["prompt_emb"]
        np.savez(os.path.join(art, "train", f"{tc.model}.npz"),
                 **{k: np.asarray(v) for k, v in out.items()})
    os.makedirs(os.path.join(art, "train_logs"), exist_ok=True)
    with open(os.path.join(art, "train_logs",
                           f"prompt_{tc.model}_{tc.variant_name()}.json"),
              "w") as f:
        json.dump(log, f)
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="ppd-m")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=350)
    ap.add_argument("--ept", type=int, default=1)
    ap.add_argument("--no-kd", action="store_true")
    ap.add_argument("--mask", default="ensemble")
    ap.add_argument("--agg", default="mean")
    ap.add_argument("--prefix", action="store_true")
    ap.add_argument("--custom-head", default="none")
    ap.add_argument("--multi-exit", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    tc = TrainCfg(model=args.model, steps=args.steps, n_ept=args.ept,
                  kd=not args.no_kd, mask_mode=args.mask, agg=args.agg,
                  prefix=args.prefix, custom_head=args.custom_head,
                  multi_exit=args.multi_exit, batch=args.batch)
    train_prompt(tc, args.out)


if __name__ == "__main__":
    main()
